"""InferenceEngine behaviour: oracle equivalence (both backends), candidate
kernel vs ref, cache survival across hot weight swaps, bucketed microbatching
with warmup-bounded compilation, torn-generation safety under concurrent
updates, latency percentiles, and the versioned update frames."""
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import transfer
from repro.common.config import FFMConfig
from repro.core import deepffm
from repro.data.synthetic import CTRStream
from repro.kernels.ffm_interaction.ffm_interaction import ffm_candidate_matrices
from repro.kernels.ffm_interaction.ref import ffm_candidate_matrices_ref
from repro.serving.engine import (InferenceEngine, batched_candidates_forward,
                                  compute_context_tails)
from repro.serving.server import FFMServer
from repro.train.loop import OnlineTrainer

CFG = FFMConfig(n_fields=12, context_fields=8, hash_space=2**13, k=4,
                mlp_hidden=(16,))


def _full_forward(cfg, params, model, ci, cv, ki, kv):
    n = ki.shape[0]
    idx = jnp.concatenate(
        [jnp.broadcast_to(jnp.asarray(ci), (n, cfg.context_fields)),
         jnp.asarray(ki)], axis=1)
    val = jnp.concatenate(
        [jnp.broadcast_to(jnp.asarray(cv), (n, cfg.context_fields)),
         jnp.asarray(kv)], axis=1)
    return np.asarray(deepffm.forward(cfg, params, idx, val, model))


@pytest.mark.parametrize("model", ["ffm", "deepffm"])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_engine_matches_full_forward(model, backend):
    """Cache + kernel composition == deepffm.forward on concatenated features."""
    params = deepffm.init_params(CFG, jax.random.PRNGKey(0), model)
    params["lr"]["w"] = jax.random.normal(
        jax.random.PRNGKey(1), params["lr"]["w"].shape) * 0.1
    eng = InferenceEngine(CFG, model, backend=backend, params=params)
    stream = CTRStream(CFG, seed=3)
    for n in (1, 5, 9):
        ci, cv, ki, kv = stream.request(n)
        got = np.asarray(eng.score(ci, cv, ki, kv))
        want = _full_forward(CFG, params, model, ci, cv, ki, kv)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)
    assert eng.hits >= 0 and eng.misses >= 1


@pytest.mark.parametrize("R,N,Fc,Fcand,K", [(1, 5, 3, 2, 4), (3, 9, 8, 4, 8),
                                            (2, 64, 4, 7, 2), (2, 6, 5, 1, 4)])
def test_candidate_kernel_matches_ref(R, N, Fc, Fcand, K):
    ks = jax.random.split(jax.random.PRNGKey(R * N + K), 5)
    ectx = jax.random.normal(ks[0], (R, Fc, Fcand, K))
    vctx = jax.random.normal(ks[1], (R, Fc))
    ecx = jax.random.normal(ks[2], (R, N, Fcand, Fc, K))
    ecc = jax.random.normal(ks[3], (R, N, Fcand, Fcand, K))
    vcand = jax.random.normal(ks[4], (R, N, Fcand))
    got_xc, got_aa = ffm_candidate_matrices(ectx, vctx, ecx, ecc, vcand,
                                            block_n=16)
    want_xc, want_aa = ffm_candidate_matrices_ref(ectx, vctx, ecx, ecc, vcand)
    np.testing.assert_allclose(np.asarray(got_xc), np.asarray(want_xc),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_aa), np.asarray(want_aa),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_cache_survives_weight_update(backend):
    """A patch+quant hot swap must not rebuild the server or drop the cache:
    a repeated context still hits, and post-swap scores match a fresh full
    forward with the new weights."""
    stream = CTRStream(CFG, seed=7)
    trainer = OnlineTrainer(CFG, lr=0.1)
    srv = FFMServer(CFG, backend=backend)
    upd = trainer.run_round(stream.batches(256, 10))
    srv.apply_update(upd, trainer.sender.manifest, trainer.params)

    engine = srv.engine
    cache_obj = engine._cache
    ci, cv, ki, kv = stream.request(6)
    srv.serve(ci, cv, ki, kv)
    srv.serve(ci, cv, ki, kv)
    assert engine.hits == 1

    upd2 = trainer.run_round(stream.batches(256, 10))  # patch+quant round
    assert transfer.unframe(upd2).is_patch
    srv.apply_update(upd2, trainer.sender.manifest, trainer.params)

    # no reconstruction on the update path: same engine, same cache object,
    # entries retained (stale ones refresh lazily on next lookup)
    assert srv.engine is engine and engine._cache is cache_obj
    assert len(cache_obj) == 1
    assert engine.generation == 2 and engine.weights_version == 2

    probs = srv.serve(ci, cv, ki, kv)   # stale entry -> recompute under new gen
    probs2 = srv.serve(ci, cv, ki, kv)  # repeated context -> cache hit again
    assert engine.hits >= 2 and srv.cache_hit_rate > 0
    np.testing.assert_allclose(probs, probs2, rtol=1e-6, atol=1e-7)
    fresh = np.asarray(jax.nn.sigmoid(
        engine.score_uncached(ci, cv, ki, kv)))
    np.testing.assert_allclose(probs, fresh, rtol=2e-4, atol=2e-5)


def test_bucketed_batching_bounds_compilations():
    """Candidate counts pad to power-of-two buckets: many request shapes, few
    compiled shapes."""
    params = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    eng = InferenceEngine(CFG, params=params, min_bucket=8)
    stream = CTRStream(CFG, seed=5)
    size_before = (batched_candidates_forward._cache_size()
                   if hasattr(batched_candidates_forward, "_cache_size") else None)
    for n in (1, 2, 3, 5, 7, 8, 6, 4):
        ci, cv, ki, kv = stream.request(n)
        out = eng.score(ci, cv, ki, kv)
        assert out.shape == (n,)
    if size_before is not None:
        # all eight shapes landed in the single (1, 8)-bucket compilation
        assert batched_candidates_forward._cache_size() - size_before <= 1
    assert eng.plan.bucket(1) == 8 and eng.plan.bucket(9) == 16


def test_warmup_precompiles_all_bucket_shapes():
    """After construction-time warmup, scoring across *all* candidate bucket
    sizes, request-batch sizes, and prefix-tail depths triggers zero new jit
    compilations — both for the candidate forward and the batched tail pass."""
    params = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    eng = InferenceEngine(CFG, params=params, min_bucket=8, prefix_stride=4,
                          warmup_buckets=(8, 32))
    before = (batched_candidates_forward._cache_size(),
              compute_context_tails._cache_size())
    stream = CTRStream(CFG, seed=5)
    for n in (1, 7, 8, 9, 16, 17, 31, 32):  # every candidate bucket
        assert eng.score(*stream.request(n)).shape == (n,)
    for r in (2, 3, 5, 8):                  # every request bucket
        eng.score_batch([stream.request(4) for _ in range(r)])
    # prefix-shared contexts: tails start at every checkpoint depth
    ci, cv, ki, kv = stream.request(4)
    eng.score(ci, cv, ki, kv)
    for keep in (4, 6):
        ci2 = ci.copy()
        ci2[keep:] = (ci2[keep:] + 1) % CFG.hash_space
        eng.score(ci2, cv, ki, kv)
    after = (batched_candidates_forward._cache_size(),
             compute_context_tails._cache_size())
    assert after == before, (before, after)


def test_concurrent_updates_never_serve_torn_generation():
    """Interleaved apply-update + scoring from threads: every score must
    correspond to exactly one installed params version, never a mix of a
    cached context partial from one generation and candidate work from
    another. Weights encode their version v (lr w = v, everything else zero),
    so any torn combination v_a*Fc + v_b*(F-Fc) of two versions is detectably
    not in the valid score set {v * F} (versions are powers of 3)."""
    cfg = CFG
    versions = [float(3 ** i) for i in range(5)]

    def params_v(v):
        p = deepffm.init_params(cfg, jax.random.PRNGKey(0), "ffm")
        p = jax.tree_util.tree_map(lambda x: jnp.zeros_like(x), p)
        p["lr"]["w"] = jnp.full_like(p["lr"]["w"], v)
        return p

    eng = InferenceEngine(cfg, "ffm", params=params_v(versions[0]),
                          warmup_buckets=(4, 8))  # pre-compile off-thread
    valid = {round(v * cfg.n_fields, 3) for v in versions}
    errors, stop = [], threading.Event()
    fc, fcand = cfg.context_fields, cfg.n_fields - cfg.context_fields

    def scorer(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            reqs = []
            for _ in range(rng.integers(1, 4)):
                ci = rng.integers(0, cfg.hash_space, fc).astype(np.int32)
                ki = rng.integers(0, cfg.hash_space,
                                  (rng.integers(1, 5), fcand)).astype(np.int32)
                reqs.append((ci, np.ones(fc, np.float32), ki,
                             np.ones(ki.shape, np.float32)))
            outs = eng.score_batch(reqs)
            got = {round(float(x), 3) for o in outs for x in np.asarray(o)}
            if not got <= valid:
                errors.append(got - valid)
            if len(got) > 1:  # one snapshot per batch -> one version per batch
                errors.append(got)

    threads = [threading.Thread(target=scorer, args=(s,)) for s in (1, 2, 3)]
    for t in threads:
        t.start()
    for v in versions[1:]:
        time.sleep(0.1)  # let scorers run against the current version
        eng.install_params(params_v(v))
    time.sleep(0.1)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors[:5]
    assert eng.generation == len(versions) - 1  # constructor params are gen 0


def test_score_batch_matches_single_requests():
    params = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    eng = InferenceEngine(CFG, params=params)
    stream = CTRStream(CFG, seed=6)
    reqs = [stream.request(n) for n in (3, 7, 5, 8, 2)]
    batched = eng.score_batch(reqs)
    for (ci, cv, ki, kv), out in zip(reqs, batched):
        single = eng.score(ci, cv, ki, kv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(single),
                                   rtol=1e-5, atol=1e-6)
    assert eng.stats.requests == len(reqs) * 2
    assert eng.stats.candidates == 2 * sum(r[2].shape[0] for r in reqs)


def test_latency_percentiles_ordered():
    params = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    eng = InferenceEngine(CFG, params=params)
    stream = CTRStream(CFG, seed=8)
    for _ in range(12):
        eng.score(*stream.request(4))
    s = eng.stats
    assert 0 < s.p50_ms <= s.p95_ms <= s.p99_ms
    assert s.predictions_per_s > 0


def test_update_frames_are_versioned():
    params = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    snd = transfer.Sender(mode="patch+quant")
    u1, u2 = snd.make_update(params), snd.make_update(params)
    f1, f2 = transfer.unframe(u1), transfer.unframe(u2)
    assert (f1.version, f2.version) == (1, 2)
    assert f1.mode == "patch+quant" and not f1.is_patch and f2.is_patch
    # explicit stamps (train loop's round counter) override the auto-counter
    u3 = snd.make_update(params, version=10)
    assert transfer.unframe(u3).version == 10
    rcv = transfer.Receiver()
    for u in (u1, u2, u3):
        rcv.apply_update(u)
    assert rcv.version == 10 and rcv.mode == "patch+quant"
