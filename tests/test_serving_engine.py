"""InferenceEngine behaviour: oracle equivalence (both backends), candidate
kernel vs ref, cache survival across hot weight swaps, bucketed microbatching,
latency percentiles, and the versioned update frames."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import transfer
from repro.common.config import FFMConfig
from repro.core import deepffm
from repro.data.synthetic import CTRStream
from repro.kernels.ffm_interaction.ffm_interaction import ffm_candidate_matrices
from repro.kernels.ffm_interaction.ref import ffm_candidate_matrices_ref
from repro.serving.engine import InferenceEngine, batched_candidates_forward
from repro.serving.server import FFMServer
from repro.train.loop import OnlineTrainer

CFG = FFMConfig(n_fields=12, context_fields=8, hash_space=2**13, k=4,
                mlp_hidden=(16,))


def _full_forward(cfg, params, model, ci, cv, ki, kv):
    n = ki.shape[0]
    idx = jnp.concatenate(
        [jnp.broadcast_to(jnp.asarray(ci), (n, cfg.context_fields)),
         jnp.asarray(ki)], axis=1)
    val = jnp.concatenate(
        [jnp.broadcast_to(jnp.asarray(cv), (n, cfg.context_fields)),
         jnp.asarray(kv)], axis=1)
    return np.asarray(deepffm.forward(cfg, params, idx, val, model))


@pytest.mark.parametrize("model", ["ffm", "deepffm"])
@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_engine_matches_full_forward(model, backend):
    """Cache + kernel composition == deepffm.forward on concatenated features."""
    params = deepffm.init_params(CFG, jax.random.PRNGKey(0), model)
    params["lr"]["w"] = jax.random.normal(
        jax.random.PRNGKey(1), params["lr"]["w"].shape) * 0.1
    eng = InferenceEngine(CFG, model, backend=backend, params=params)
    stream = CTRStream(CFG, seed=3)
    for n in (1, 5, 9):
        ci, cv, ki, kv = stream.request(n)
        got = np.asarray(eng.score(ci, cv, ki, kv))
        want = _full_forward(CFG, params, model, ci, cv, ki, kv)
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-5)
    assert eng.hits >= 0 and eng.misses >= 1


@pytest.mark.parametrize("R,N,Fc,Fcand,K", [(1, 5, 3, 2, 4), (3, 9, 8, 4, 8),
                                            (2, 64, 4, 7, 2), (2, 6, 5, 1, 4)])
def test_candidate_kernel_matches_ref(R, N, Fc, Fcand, K):
    ks = jax.random.split(jax.random.PRNGKey(R * N + K), 5)
    ectx = jax.random.normal(ks[0], (R, Fc, Fcand, K))
    vctx = jax.random.normal(ks[1], (R, Fc))
    ecx = jax.random.normal(ks[2], (R, N, Fcand, Fc, K))
    ecc = jax.random.normal(ks[3], (R, N, Fcand, Fcand, K))
    vcand = jax.random.normal(ks[4], (R, N, Fcand))
    got_xc, got_aa = ffm_candidate_matrices(ectx, vctx, ecx, ecc, vcand,
                                            block_n=16)
    want_xc, want_aa = ffm_candidate_matrices_ref(ectx, vctx, ecx, ecc, vcand)
    np.testing.assert_allclose(np.asarray(got_xc), np.asarray(want_xc),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(got_aa), np.asarray(want_aa),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_cache_survives_weight_update(backend):
    """A patch+quant hot swap must not rebuild the server or drop the cache:
    a repeated context still hits, and post-swap scores match a fresh full
    forward with the new weights."""
    stream = CTRStream(CFG, seed=7)
    trainer = OnlineTrainer(CFG, lr=0.1)
    srv = FFMServer(CFG, backend=backend)
    upd = trainer.run_round(stream.batches(256, 10))
    srv.apply_update(upd, trainer.sender.manifest, trainer.params)

    engine = srv.engine
    cache_obj = engine._cache
    ci, cv, ki, kv = stream.request(6)
    srv.serve(ci, cv, ki, kv)
    srv.serve(ci, cv, ki, kv)
    assert engine.hits == 1

    upd2 = trainer.run_round(stream.batches(256, 10))  # patch+quant round
    assert transfer.unframe(upd2).is_patch
    srv.apply_update(upd2, trainer.sender.manifest, trainer.params)

    # no reconstruction on the update path: same engine, same cache object,
    # entries retained (stale ones refresh lazily on next lookup)
    assert srv.engine is engine and engine._cache is cache_obj
    assert len(cache_obj) == 1
    assert engine.generation == 2 and engine.weights_version == 2

    probs = srv.serve(ci, cv, ki, kv)   # stale entry -> recompute under new gen
    probs2 = srv.serve(ci, cv, ki, kv)  # repeated context -> cache hit again
    assert engine.hits >= 2 and srv.cache_hit_rate > 0
    np.testing.assert_allclose(probs, probs2, rtol=1e-6, atol=1e-7)
    fresh = np.asarray(jax.nn.sigmoid(
        engine.score_uncached(ci, cv, ki, kv)))
    np.testing.assert_allclose(probs, fresh, rtol=2e-4, atol=2e-5)


def test_bucketed_batching_bounds_compilations():
    """Candidate counts pad to power-of-two buckets: many request shapes, few
    compiled shapes."""
    params = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    eng = InferenceEngine(CFG, params=params, min_bucket=8)
    stream = CTRStream(CFG, seed=5)
    size_before = (batched_candidates_forward._cache_size()
                   if hasattr(batched_candidates_forward, "_cache_size") else None)
    for n in (1, 2, 3, 5, 7, 8, 6, 4):
        ci, cv, ki, kv = stream.request(n)
        out = eng.score(ci, cv, ki, kv)
        assert out.shape == (n,)
    if size_before is not None:
        # all eight shapes landed in the single (1, 8)-bucket compilation
        assert batched_candidates_forward._cache_size() - size_before <= 1
    assert eng.plan.bucket(1) == 8 and eng.plan.bucket(9) == 16


def test_score_batch_matches_single_requests():
    params = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    eng = InferenceEngine(CFG, params=params)
    stream = CTRStream(CFG, seed=6)
    reqs = [stream.request(n) for n in (3, 7, 5, 8, 2)]
    batched = eng.score_batch(reqs)
    for (ci, cv, ki, kv), out in zip(reqs, batched):
        single = eng.score(ci, cv, ki, kv)
        np.testing.assert_allclose(np.asarray(out), np.asarray(single),
                                   rtol=1e-5, atol=1e-6)
    assert eng.stats.requests == len(reqs) * 2
    assert eng.stats.candidates == 2 * sum(r[2].shape[0] for r in reqs)


def test_latency_percentiles_ordered():
    params = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    eng = InferenceEngine(CFG, params=params)
    stream = CTRStream(CFG, seed=8)
    for _ in range(12):
        eng.score(*stream.request(4))
    s = eng.stats
    assert 0 < s.p50_ms <= s.p95_ms <= s.p99_ms
    assert s.predictions_per_s > 0


def test_update_frames_are_versioned():
    params = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    snd = transfer.Sender(mode="patch+quant")
    u1, u2 = snd.make_update(params), snd.make_update(params)
    f1, f2 = transfer.unframe(u1), transfer.unframe(u2)
    assert (f1.version, f2.version) == (1, 2)
    assert f1.mode == "patch+quant" and not f1.is_patch and f2.is_patch
    # explicit stamps (train loop's round counter) override the auto-counter
    u3 = snd.make_update(params, version=10)
    assert transfer.unframe(u3).version == 10
    rcv = transfer.Receiver()
    for u in (u1, u2, u3):
        rcv.apply_update(u)
    assert rcv.version == 10 and rcv.mode == "patch+quant"
