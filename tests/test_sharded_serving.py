"""Sharded multi-worker serving: topology, scatter-gather router, fan-out.

The fleet contracts (see ``serving/shard_router.py`` module docstring):

* **Topology exactness** — contiguous LR-block-aligned ranges make
  ``quantize(shard_slice(w)) == shard_slice(quantize(w))`` byte-for-byte,
  and shard params concatenate back to the full-space pytree.
* **Cross-N bit identity** — router scores are bit-identical for every
  shard count N (quantized and f32 fleets, divisible and non-divisible
  splits), and within quantization tolerance of the ``deepffm.forward``
  oracle. This is the partial-sum reduction contract: one fixed einsum
  form over compacted entries + fixed-shard-order disjoint scatter.
* **Fan-out byte exactness** — per-shard ``ShardedSender`` frames decode to
  exactly the shard slices of the full-space frames at every generation
  (full + deltas), so the streamed fleet equals the single-engine ingest
  oracle byte-for-byte in its int8 tables.
* **Failure modes** — killing a shard degrades (zero contributions,
  ``degraded`` flag) without a request-path exception; a torn generation
  vector (one shard updated, one behind) still serves; ``rotate_shard``
  swaps a successor in without breaking the delta chain.
"""
import numpy as np
import pytest

import jax

from repro.checkpoint import layout, transfer
from repro.common.config import FFMConfig
from repro.core import deepffm
from repro.core import quantization as Q
from repro.launch import topology
from repro.serving.engine import InferenceEngine
from repro.serving.shard_router import ShardRouter
from repro.train.pipeline import TrainingPipeline

pytestmark = pytest.mark.lockcheck

CFG = FFMConfig(n_fields=8, context_fields=5, hash_space=1024, k=4,
                mlp_hidden=(16,))


@pytest.fixture(scope="module")
def params():
    p = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    return jax.tree_util.tree_map(np.asarray, p)


def _requests(rng, n_req=5, n_cand=7, cfg=CFG):
    fc, fcand = cfg.context_fields, cfg.n_fields - cfg.context_fields
    return [(rng.integers(0, cfg.hash_space, fc).astype(np.int32),
             rng.standard_normal(fc).astype(np.float32),
             rng.integers(0, cfg.hash_space, (n_cand, fcand)).astype(np.int32),
             rng.standard_normal((n_cand, fcand)).astype(np.float32))
            for _ in range(n_req)]


# ---------------------------------------------------------------------------
# Topology
# ---------------------------------------------------------------------------

def test_shard_ranges_cover_aligned():
    ranges = topology.shard_ranges(1024, 3)
    assert ranges[0][0] == 0 and ranges[-1][1] == 1024
    for (_, hi), (lo, _) in zip(ranges, ranges[1:]):
        assert hi == lo
    for lo, _ in ranges:
        assert lo % Q.LR_BLOCK == 0
    # ownership is total and consistent with the ranges
    owner = topology.owner_of(ranges, np.arange(1024))
    for s, (lo, hi) in enumerate(ranges):
        assert (owner[lo:hi] == s).all()


def test_shard_ranges_too_many_shards():
    with pytest.raises(ValueError):
        topology.shard_ranges(128, 3)  # only 2 alignment units


def test_row_sharded_paths_from_specs():
    assert topology.row_sharded_paths(CFG, "deepffm") == ("ffm/emb", "lr/w")


def test_quantize_commutes_with_slicing(params):
    """quantize(shard_slice(w)) == shard_slice(quantize(w)) byte-for-byte."""
    topo = topology.ShardTopology.build(CFG, "deepffm", 3)
    full_q = Q.quantize_params_rows(params)
    for s, (lo, hi) in enumerate(topo.ranges):
        local_q = Q.quantize_params_rows(topo.shard_params(params, s))
        sliced = topo.shard_params(full_q, s)
        for key in ("codes", "scale", "zero"):
            assert np.array_equal(local_q["ffm"]["emb"][key],
                                  sliced["ffm"]["emb"][key])
            assert np.array_equal(local_q["lr"]["w"][key],
                                  sliced["lr"]["w"][key])


def test_materialized_params_roundtrip(params):
    router = ShardRouter(CFG, n_shards=3, params=params, quantized=True)
    full_q = Q.quantize_params_rows(params)
    mat = router.materialized_params()
    router.close()
    for key in ("codes", "scale", "zero"):
        assert np.array_equal(mat["ffm"]["emb"][key], full_q["ffm"]["emb"][key])
        assert np.array_equal(mat["lr"]["w"][key], full_q["lr"]["w"][key])


# ---------------------------------------------------------------------------
# Cross-N bit identity + oracle tolerance (the reduction contract)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantized", [True, False])
def test_scores_bit_identical_across_shard_counts(params, quantized):
    rng = np.random.default_rng(1)
    reqs = _requests(rng)
    outs = {}
    for n in (1, 2, 3, 4):  # 3: non-divisible split
        router = ShardRouter(CFG, n_shards=n, params=params,
                             quantized=quantized)
        outs[n] = np.concatenate(router.score_batch(reqs))
        router.close()
    for n in (2, 3, 4):
        assert np.array_equal(outs[n], outs[1]), f"N={n} bits != N=1"


def test_router_within_tolerance_of_forward_oracle(params):
    rng = np.random.default_rng(2)
    reqs = _requests(rng)
    router = ShardRouter(CFG, n_shards=2, params=params, quantized=False)
    got = np.concatenate(router.score_batch(reqs))
    want = np.concatenate([
        np.asarray(router.score_uncached(ci, cv, ki, kv))
        for ci, cv, ki, kv in reqs])
    router.close()
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_quantized_router_matches_single_quantized_engine(params):
    rng = np.random.default_rng(3)
    reqs = _requests(rng)
    router = ShardRouter(CFG, n_shards=2, params=params, quantized=True)
    single = InferenceEngine(CFG, params=params, quantized=True)
    got = np.concatenate(router.score_batch(reqs))
    want = np.concatenate(single.score_batch(reqs))
    router.close()
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_resident_bytes_split_across_shards(params):
    single = InferenceEngine(CFG, params=params, quantized=True)
    router = ShardRouter(CFG, n_shards=4, params=params, quantized=True)
    per_shard = router.shard_resident_bytes()
    # tables split ~1/N; the small replicated head rides along per shard
    assert max(per_shard) < single.resident_weight_bytes / 2
    assert sum(per_shard) == router.resident_weight_bytes
    router.close()


# ---------------------------------------------------------------------------
# Fan-out delta ingestion
# ---------------------------------------------------------------------------

def _mk_batch(rng, cfg=CFG, n=64):
    return {"idx": rng.integers(0, cfg.hash_space,
                                (n, cfg.n_fields)).astype(np.int32),
            "val": rng.standard_normal((n, cfg.n_fields)).astype(np.float32),
            "label": rng.integers(0, 2, n).astype(np.float32)}


def test_sharded_frames_decode_to_slices_of_full_frames():
    """Per-shard delta-frame filtering vs the full-space ingest oracle,
    byte-for-byte, at every generation while deltas stream."""
    rng = np.random.default_rng(7)
    ranges = topology.shard_ranges(CFG.hash_space, 2)
    pipe_s = TrainingPipeline(CFG, lr=0.05, seed=3, shard_ranges=ranges)
    pipe_f = TrainingPipeline(CFG, lr=0.05, seed=3)
    like = jax.tree_util.tree_map(np.asarray, pipe_f.params)
    rec_full = transfer.Receiver()
    recs = [transfer.Receiver() for _ in ranges]
    kinds = []
    for rnd in range(3):
        batch = [_mk_batch(rng)]
        frames = pipe_s.run_round(iter(batch))
        full = pipe_f.run_round(iter(batch))
        kinds.append(transfer.unframe(full).kind)
        assert [transfer.unframe(f).kind for f in frames] == \
            [transfer.unframe(full).kind] * len(ranges)  # grid coherence
        rec_full.apply_update(full)
        want = rec_full.materialize(manifest=pipe_f.sender.manifest,
                                    like=like)
        want_flat = dict(layout.flatten_with_paths(want))
        for s, (frame, rec) in enumerate(zip(frames, recs)):
            rec.apply_update(frame)
            assert rec.version == transfer.unframe(full).version
            got = rec.materialize(manifest=pipe_s.sender.manifests[s])
            lo, hi = ranges[s]
            for path, arr in got.items():
                ref = want_flat[path]
                if path in ("ffm/emb", "lr/w"):
                    ref = ref[lo:hi]
                assert np.array_equal(np.asarray(ref, np.float32),
                                      np.asarray(arr, np.float32)), \
                    f"round {rnd} shard {s} {path}"
    assert kinds[0] == transfer.KIND_FULL  # first round ships full
    assert transfer.KIND_DELTA in kinds[1:]  # steady state goes delta


def test_streamed_fleet_matches_single_engine_ingest(params):
    """Stream full + delta rounds through per-shard pipes; the fleet's int8
    tables must be byte-exact slices of the single engine's, the generation
    vector must advance, and scores must match within tolerance."""
    rng = np.random.default_rng(8)
    ranges = topology.shard_ranges(CFG.hash_space, 2)
    pipe_s = TrainingPipeline(CFG, lr=0.05, seed=4, shard_ranges=ranges)
    pipe_f = TrainingPipeline(CFG, lr=0.05, seed=4)
    router = ShardRouter(CFG, n_shards=2, quantized=True)
    single = InferenceEngine(CFG, quantized=True)
    like = jax.tree_util.tree_map(np.asarray, pipe_f.params)

    rounds = []
    for _ in range(3):
        batch = [_mk_batch(rng)]
        rounds.append((pipe_s.run_round(iter(batch)),
                       pipe_f.run_round(iter(batch))))
    router.configure_fanout(pipe_s.sender.manifests, like)
    for frames, full in rounds:
        assert router.submit_updates(frames) == 2
        single.submit_update(full, manifest=pipe_f.sender.manifest,
                             like_params=like)
    gens = router.flush_updates()
    single.update_pipe().flush()
    assert all(g == (3, 3) for g in gens)
    assert router.weights_version == 3

    sp = single.params
    for s, shard in enumerate(router.shards):
        lo, hi = ranges[s]
        for key in ("codes", "scale", "zero"):
            assert np.array_equal(shard.params["ffm"]["emb"][key],
                                  sp["ffm"]["emb"][key][lo:hi])
    reqs = _requests(rng)
    got = np.concatenate(router.score_batch(reqs))
    want = np.concatenate(single.score_batch(reqs))
    router.close()
    np.testing.assert_allclose(got, want, atol=1e-5)


def test_streamed_bits_invariant_across_shard_counts():
    """N=2 streamed fleet == N=1 streamed fleet bit-for-bit at the final
    generation (the reduction contract holds for ingested weights too)."""
    rng = np.random.default_rng(9)
    outs = {}
    for n in (1, 2):
        pipe = TrainingPipeline(
            CFG, lr=0.05, seed=5,
            shard_ranges=topology.shard_ranges(CFG.hash_space, n))
        router = ShardRouter(CFG, n_shards=n, quantized=True)
        like = jax.tree_util.tree_map(np.asarray, pipe.params)
        batch_rng = np.random.default_rng(10)  # same batches for both fleets
        frames = [pipe.run_round(iter([_mk_batch(batch_rng)]))
                  for _ in range(2)]
        router.configure_fanout(pipe.sender.manifests, like)
        for f in frames:
            router.submit_updates(f)
        router.flush_updates()
        req_rng = np.random.default_rng(11)
        outs[n] = np.concatenate(router.score_batch(_requests(req_rng)))
        router.close()
    assert np.array_equal(outs[2], outs[1])


# ---------------------------------------------------------------------------
# Failure modes
# ---------------------------------------------------------------------------

def test_kill_shard_degrades_gracefully(params):
    rng = np.random.default_rng(12)
    reqs = _requests(rng)
    router = ShardRouter(CFG, n_shards=3, params=params, quantized=True)
    before = np.concatenate(router.score_batch(reqs))
    router.kill_shard(1)
    assert router.degraded
    after = np.concatenate(router.score_batch(reqs))  # must not raise
    assert np.isfinite(after).all()
    assert not np.array_equal(before, after)  # the dead rows really zeroed
    assert router.fleet_generations()[1] is None
    # oracle path still works against the zero-filled materialized tables
    o = router.score_uncached(*reqs[0])
    assert np.isfinite(np.asarray(o)).all()
    router.close()


def test_torn_generation_vector_serves(params):
    """One shard a generation ahead of the other: the router serves a mixed
    snapshot without raising, and converges once both shards flush."""
    rng = np.random.default_rng(13)
    ranges = topology.shard_ranges(CFG.hash_space, 2)
    pipe = TrainingPipeline(CFG, lr=0.05, seed=6, shard_ranges=ranges)
    router = ShardRouter(CFG, n_shards=2, quantized=True)
    like = jax.tree_util.tree_map(np.asarray, pipe.params)
    f0 = pipe.run_round(iter([_mk_batch(rng)]))
    f1 = pipe.run_round(iter([_mk_batch(rng)]))
    router.configure_fanout(pipe.sender.manifests, like)
    router.submit_updates(f0)
    router.flush_updates()
    # tear: only shard 0 gets round 2
    router.shards[0].submit_update(f1[0])
    router.shards[0]._pipe.flush()
    gens = router.fleet_generations()
    assert gens[0][1] == 2 and gens[1][1] == 1  # torn vector
    reqs = _requests(rng)
    torn = np.concatenate(router.score_batch(reqs))  # must not raise
    assert np.isfinite(torn).all()
    # heal: shard 1 catches up; parity with an untorn fleet ingest
    router.shards[1].submit_update(f1[1])
    router.flush_updates()
    assert all(g[1] == 2 for g in router.fleet_generations())
    healed = np.concatenate(router.score_batch(reqs))
    other = ShardRouter(CFG, n_shards=2, quantized=True)
    other.configure_fanout(pipe.sender.manifests, like)
    for f in (f0, f1):
        other.submit_updates(f)
    other.flush_updates()
    assert np.array_equal(healed,
                          np.concatenate(other.score_batch(reqs)))
    router.close()
    other.close()


def test_rotate_shard_swaps_successor_and_keeps_delta_chain(params):
    rng = np.random.default_rng(14)
    ranges = topology.shard_ranges(CFG.hash_space, 2)
    pipe = TrainingPipeline(CFG, lr=0.05, seed=7, shard_ranges=ranges)
    router = ShardRouter(CFG, n_shards=2, quantized=True)
    like = jax.tree_util.tree_map(np.asarray, pipe.params)
    f0 = pipe.run_round(iter([_mk_batch(rng)]))
    router.configure_fanout(pipe.sender.manifests, like)
    router.submit_updates(f0)
    router.flush_updates()
    reqs = _requests(rng)
    before = np.concatenate(router.score_batch(reqs))
    old = router.shards[0]
    succ = router.rotate_shard(0)
    assert router.shards[0] is succ and succ is not old
    assert succ.generation >= old.generation  # monotonic across the swap
    assert np.array_equal(np.concatenate(router.score_batch(reqs)), before)
    # the delta chain continues through the re-pointed pipe
    f1 = pipe.run_round(iter([_mk_batch(rng)]))
    assert transfer.unframe(f1[0]).kind == transfer.KIND_DELTA
    router.submit_updates(f1)
    router.flush_updates()
    assert succ.weights_version == 2
    assert np.isfinite(np.concatenate(router.score_batch(reqs))).all()
    router.close()


def test_engine_rotate_adopts_params_and_version(params):
    eng = InferenceEngine(CFG, params=params, quantized=True)
    rng = np.random.default_rng(15)
    reqs = _requests(rng)
    want = np.concatenate(eng.score_batch(reqs))
    succ = eng.rotate()
    assert succ.params is eng.params  # adopted by reference, not requantized
    assert succ.generation == eng.generation
    assert succ.weights_version == eng.weights_version
    assert np.array_equal(np.concatenate(succ.score_batch(reqs)), want)


# ---------------------------------------------------------------------------
# Gather-cliff calibration (satellites 1+2)
# ---------------------------------------------------------------------------

def test_cliff_env_kill_switch(monkeypatch):
    from repro.kernels.row_gather import ops as rg_ops

    monkeypatch.setenv("REPRO_CLIFF_CALIBRATE", "0")
    assert rg_ops.cliff_rows() == rg_ops.CLIFF_ROWS


def test_cliff_calibration_cached_and_bounded(monkeypatch):
    from repro.kernels.row_gather import ops as rg_ops

    monkeypatch.delenv("REPRO_CLIFF_CALIBRATE", raising=False)
    monkeypatch.setattr(rg_ops, "_calibrated", None)
    got = rg_ops.cliff_rows()
    assert min(rg_ops._PROBE_SIZES) <= got <= rg_ops._PROBE_MAX
    assert rg_ops._calibrated == got  # cached per process
    monkeypatch.setattr(rg_ops, "calibrate_cliff_rows",
                        lambda *a, **k: (_ for _ in ()).throw(RuntimeError()))
    monkeypatch.setattr(rg_ops, "_calibrated", None)
    assert rg_ops.cliff_rows() == rg_ops.CLIFF_ROWS  # probe failure fallback


def test_f32_host_gather_parity(params):
    """Satellite 2: an f32 engine forced onto the host packed pre-gather
    scores bit-compatible (within float tolerance) with the in-trace one."""
    rng = np.random.default_rng(16)
    reqs = _requests(rng)
    host = InferenceEngine(CFG, params=params, host_gather=True)
    trace = InferenceEngine(CFG, params=params, host_gather=False)
    assert host.host_gather and not trace.host_gather
    got = np.concatenate(host.score_batch(reqs))
    want = np.concatenate(trace.score_batch(reqs))
    np.testing.assert_allclose(got, want, atol=1e-5)
