"""Property tests for the byte-level patcher (paper §6)."""
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.core import patcher


@given(st.lists(st.integers(min_value=0, max_value=2**63 - 1), max_size=200))
@settings(max_examples=200, deadline=None)
def test_varint_roundtrip(values):
    v = np.asarray(values, np.uint64)
    assert (patcher.varint_decode(patcher.varint_encode(v)) == v).all()


@given(
    st.binary(min_size=1, max_size=4096),
    st.lists(st.tuples(st.integers(0, 4095), st.integers(0, 255)), max_size=64),
)
@settings(max_examples=200, deadline=None)
def test_patch_roundtrip(old, edits):
    new = bytearray(old)
    for pos, val in edits:
        if pos < len(new):
            new[pos] = val
    new = bytes(new)
    p = patcher.diff(old, new)
    assert patcher.apply_patch(old, p) == new


def test_patch_identical_is_tiny():
    buf = np.random.default_rng(0).integers(0, 256, 1_000_000, np.uint8).tobytes()
    p = patcher.diff(buf, buf)
    assert len(p) < 100
    assert patcher.apply_patch(buf, p) == buf


def test_patch_size_scales_with_changes():
    rng = np.random.default_rng(1)
    old = rng.integers(0, 256, 1_000_000, np.uint8)
    sizes = []
    for n_changes in (10, 1000, 100_000):
        new = old.copy()
        pos = rng.choice(old.size, n_changes, replace=False)
        new[pos] = ((new[pos].astype(np.int16) + 1) % 256).astype(np.uint8)
        sizes.append(len(patcher.diff(old.tobytes(), new.tobytes())))
    assert sizes[0] < sizes[1] < sizes[2]
    assert sizes[2] < old.size  # still smaller than shipping the file


def test_patch_rejects_size_mismatch():
    with pytest.raises(ValueError):
        patcher.diff(b"abc", b"abcd")


def test_patch_relative_offsets_beat_absolute():
    """The paper's point: relative offsets + varints compress dense changes."""
    rng = np.random.default_rng(2)
    old = rng.integers(0, 256, 2_000_000, np.uint8)
    new = old.copy()
    # clustered changes late in the buffer (large absolute indices, small gaps)
    pos = 1_900_000 + np.arange(0, 50_000, 5)
    new[pos] = ((new[pos].astype(np.int16) + 1) % 256).astype(np.uint8)
    p = patcher.diff(old.tobytes(), new.tobytes())
    naive = pos.size * (8 + 1)  # absolute u64 index + byte
    assert len(p) < naive
