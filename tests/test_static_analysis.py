"""Machine-checked invariants (PR 10): the linter and the lock witness.

Three layers:

* **The gate** — ``run_lint()`` over the real ``src/repro`` tree is clean,
  which is exactly what ``python -m repro.analysis`` and the benchmark
  smoke run enforce.
* **Per-rule fixtures** — every registered rule has at least one firing
  and one non-firing source fixture, linted from a tmp tree so the rule
  semantics (not the current state of the repo) are what is pinned.
* **The runtime witness** — wraps real locks, fires on an acquisition
  against the declared partial order in ``analysis/lock_order.py``, stays
  silent on the declared order, and install/uninstall round-trips the
  serving constructors.
"""
import textwrap
import threading

import pytest

from repro.analysis import lock_order, lock_witness, run_lint
from repro.analysis.lint import Violation
from repro.analysis.rules import ALL_RULES, rule_ids


# ---------------------------------------------------------------------------
# the gate
# ---------------------------------------------------------------------------

def test_src_tree_is_clean():
    violations = run_lint()
    assert not violations, "linter violations at HEAD:\n" + "\n".join(
        str(v) for v in violations)


def test_violation_format_is_file_line_rule_message():
    v = Violation("src/repro/x.py", 7, "lock-order", "bad nesting")
    assert str(v) == "src/repro/x.py:7 lock-order bad nesting"


def test_rule_registry_covers_the_documented_ids():
    assert set(rule_ids()) == {
        "lock-order", "guarded-by", "trace-purity", "np-purity",
        "thread-daemon", "silent-except", "jit-cache"}


# ---------------------------------------------------------------------------
# per-rule fixtures
# ---------------------------------------------------------------------------

def _lint(tmp_path, source, rule_id=None, name="mod.py"):
    p = tmp_path / name
    p.parent.mkdir(parents=True, exist_ok=True)
    p.write_text(textwrap.dedent(source))
    rules = None
    if rule_id is not None:
        rules = [r() for r in ALL_RULES if r.id == rule_id]
        assert rules or rule_id == "bad-pragma", f"unknown rule {rule_id}"
    return run_lint([p], rules=rules, root=tmp_path)


def test_lock_order_fires_on_inverted_with_nesting(tmp_path):
    vs = _lint(tmp_path, """
        class Router:
            def bad(self):
                with self._ingest_lock:
                    with self._fleet_lock:
                        pass
        """, "lock-order")
    assert len(vs) == 1 and vs[0].rule == "lock-order"
    assert "ShardRouter._fleet_lock" in vs[0].message


def test_lock_order_fires_on_acquire_release_idiom(tmp_path):
    vs = _lint(tmp_path, """
        class Router:
            def bad(self):
                self._ingest_lock.acquire()
                try:
                    with self._fleet_lock:
                        pass
                finally:
                    self._ingest_lock.release()
        """, "lock-order")
    assert len(vs) == 1 and vs[0].rule == "lock-order"


def test_lock_order_silent_on_declared_order(tmp_path):
    vs = _lint(tmp_path, """
        class Router:
            def good(self):
                with self._fleet_lock:
                    with self._ingest_lock:
                        pass

            def sequential(self):
                with self._ingest_lock:
                    pass
                with self._fleet_lock:
                    pass
        """, "lock-order")
    assert vs == []


def test_lock_order_fires_on_equal_rank_peer_nesting(tmp_path):
    vs = _lint(tmp_path, """
        def bad(a, b):
            with a._ingest_lock:
                with b._ingest_lock:
                    pass
        """, "lock-order")
    assert len(vs) == 1 and "no declared order" in vs[0].message


def test_guarded_by_fires_on_unlocked_write(tmp_path):
    vs = _lint(tmp_path, """
        import threading

        class Engine:
            def __init__(self):
                self._table = {}  # guarded-by: _lock
                self._lock = threading.Lock()

            def bad(self):
                self._table = {}
        """, "guarded-by")
    assert len(vs) == 1 and vs[0].rule == "guarded-by"
    assert "_table" in vs[0].message and "_lock" in vs[0].message


def test_guarded_by_silent_under_lock_and_requires_lock(tmp_path):
    vs = _lint(tmp_path, """
        import threading

        class Engine:
            def __init__(self):
                self._table = {}  # guarded-by: _lock
                self._lock = threading.Lock()

            def good(self):
                with self._lock:
                    self._table = {}

            def helper(self):  # requires-lock: _lock
                self._table["k"] = 1
        """, "guarded-by")
    assert vs == []


def test_guarded_by_calls_variant_binds_method_calls(tmp_path):
    vs = _lint(tmp_path, """
        import threading

        class Engine:
            def __init__(self):
                self._cache = Cache()  # guarded-by(calls): _lock
                self._spare = Cache()  # guarded-by: _lock
                self._lock = threading.Lock()

            def bad(self):
                self._cache.insert(1)

            def plain_guard_allows_calls(self):
                return self._spare.lookup(1)
        """, "guarded-by")
    assert len(vs) == 1
    assert ".insert()" in vs[0].message


def test_trace_purity_fires_inside_jitted_function(tmp_path):
    vs = _lint(tmp_path, """
        import jax
        import numpy as np

        @jax.jit
        def fwd(x):
            return np.sin(x)
        """, "trace-purity")
    assert len(vs) == 1 and "np.sin" in vs[0].message


def test_trace_purity_follows_module_local_calls(tmp_path):
    vs = _lint(tmp_path, """
        import time

        import jax

        def helper(x):
            time.sleep(0.1)
            return x

        @jax.jit
        def fwd(x):
            return helper(x)
        """, "trace-purity")
    assert len(vs) == 1 and "time.sleep" in vs[0].message


def test_trace_purity_silent_on_host_functions(tmp_path):
    vs = _lint(tmp_path, """
        import time

        import numpy as np

        def host(x):
            time.sleep(0.0)
            return np.asarray(x)
        """, "trace-purity")
    assert vs == []


def test_np_purity_fires_on_jnp_in_np_function(tmp_path):
    vs = _lint(tmp_path, """
        import jax.numpy as jnp

        def gather_np(x):
            return jnp.sum(x)
        """, "np-purity")
    assert len(vs) == 1 and "gather_np" in vs[0].message


def test_np_purity_silent_on_numpy_only(tmp_path):
    vs = _lint(tmp_path, """
        import numpy as np

        def gather_np(x):
            return np.sum(x)
        """, "np-purity")
    assert vs == []


def test_thread_daemon_fires_on_orphan_thread(tmp_path):
    vs = _lint(tmp_path, """
        import threading

        def spawn():
            t = threading.Thread(target=print)
            t.start()
        """, "thread-daemon")
    assert len(vs) == 1 and vs[0].rule == "thread-daemon"


def test_thread_daemon_silent_on_daemon_join_and_class_close(tmp_path):
    vs = _lint(tmp_path, """
        import threading
        from concurrent.futures import ThreadPoolExecutor

        def daemonized():
            threading.Thread(target=print, daemon=True).start()

        def joined():
            t = threading.Thread(target=print)
            t.start()
            t.join()

        class Owner:
            def start(self):
                self._t = threading.Thread(target=print)
                self._t.start()
                self._pool = ThreadPoolExecutor(2)

            def close(self):
                self._t.join()
                self._pool.shutdown()
        """, "thread-daemon")
    assert vs == []


def test_silent_except_fires_on_bare_and_swallowing_handlers(tmp_path):
    vs = _lint(tmp_path, """
        def bare(f):
            try:
                f()
            except:
                pass

        def swallow(f):
            for _ in range(3):
                try:
                    f()
                except Exception:
                    continue
        """, "silent-except")
    assert len(vs) == 2 and all(v.rule == "silent-except" for v in vs)


def test_silent_except_silent_when_error_is_latched_or_narrow(tmp_path):
    vs = _lint(tmp_path, """
        def latched(self, f):
            try:
                f()
            except Exception as e:
                self.last_error = e

        def narrow(f):
            try:
                f()
            except KeyError:
                pass
        """, "silent-except")
    assert vs == []


def test_jit_cache_fires_on_device_arrays_in_serving_hot_path(tmp_path):
    vs = _lint(tmp_path, """
        import jax.numpy as jnp

        class Engine:
            def _forward_args(self, x):
                return jnp.asarray(x)

            def planner(self, x):  # jit-cache: numpy-keyed
                return jnp.zeros(3)
        """, "jit-cache", name="serving/hot.py")
    assert len(vs) == 2 and all(v.rule == "jit-cache" for v in vs)


def test_jit_cache_scoped_to_serving_and_numpy_is_fine(tmp_path):
    outside = _lint(tmp_path, """
        import jax.numpy as jnp

        def _forward_args(x):
            return jnp.asarray(x)
        """, "jit-cache", name="train/hot.py")
    assert outside == []
    numpy_only = _lint(tmp_path, """
        import numpy as np

        def _forward_args(x):
            return np.ascontiguousarray(x)
        """, "jit-cache", name="serving/ok.py")
    assert numpy_only == []


def test_pragma_suppresses_with_reason_and_flags_without(tmp_path):
    suppressed = _lint(tmp_path, """
        def swallow(f):
            try:
                f()
            except Exception:  # lint: ignore[silent-except] fixture-only
                pass
        """, "silent-except")
    assert suppressed == []
    bad = _lint(tmp_path, """
        def swallow(f):
            try:
                f()
            except Exception:  # lint: ignore[silent-except]
                pass
        """, "bad-pragma")
    assert len(bad) == 1 and bad[0].rule == "bad-pragma"


# ---------------------------------------------------------------------------
# the runtime witness
# ---------------------------------------------------------------------------

def test_witness_fires_on_inverted_acquisition():
    session = lock_witness.Session()
    ingest = lock_witness.wrap(threading.Lock(),
                               "UpdatePipe._ingest_lock", session)
    fleet = lock_witness.wrap(threading.Lock(),
                              "ShardRouter._fleet_lock", session)
    with ingest:
        with fleet:  # rank 10 under rank 20: against the declared order
            pass
    assert len(session.violations) == 1
    v = session.violations[0]
    assert v.acquiring == "ShardRouter._fleet_lock"
    assert v.held == "UpdatePipe._ingest_lock"
    assert "contradicts" in str(v)


def test_witness_silent_on_declared_order_and_reentry():
    session = lock_witness.Session()
    fleet = lock_witness.wrap(threading.Lock(),
                              "ShardRouter._fleet_lock", session)
    ingest = lock_witness.wrap(threading.Lock(),
                               "UpdatePipe._ingest_lock", session)
    with fleet:
        with ingest:
            pass
    with ingest:  # sequential re-acquisition is fine
        pass
    assert session.violations == []


def test_witness_fires_on_equal_rank_peer_instances():
    session = lock_witness.Session()
    a = lock_witness.wrap(threading.Lock(), "ReplicaHealth._lock", session)
    b = lock_witness.wrap(threading.Lock(), "ReplicaHealth._lock", session)
    with a:
        with b:  # two unordered peers nested: latent deadlock
            pass
    assert len(session.violations) == 1


def test_witness_held_stacks_are_per_thread():
    session = lock_witness.Session()
    ingest = lock_witness.wrap(threading.Lock(),
                               "UpdatePipe._ingest_lock", session)
    fleet = lock_witness.wrap(threading.Lock(),
                              "ShardRouter._fleet_lock", session)
    taken = threading.Event()
    release = threading.Event()

    def holder():
        with ingest:
            taken.set()
            release.wait(5.0)

    t = threading.Thread(target=holder)
    t.start()
    taken.wait(5.0)
    with fleet:  # this thread holds nothing else: not a violation
        pass
    release.set()
    t.join(5.0)
    assert session.violations == []


def test_witness_deactivated_session_stops_recording():
    session = lock_witness.Session()
    ingest = lock_witness.wrap(threading.Lock(),
                               "UpdatePipe._ingest_lock", session)
    fleet = lock_witness.wrap(threading.Lock(),
                              "ShardRouter._fleet_lock", session)
    session.active = False
    with ingest:
        with fleet:
            pass
    assert session.violations == []


def test_witness_install_wraps_new_objects_and_uninstall_restores():
    from repro.serving.update_pipe import UpdatePipe

    session = lock_witness.install()
    try:
        pipe = UpdatePipe(object())
        assert isinstance(pipe._ingest_lock, lock_witness.WitnessLock)
        assert isinstance(pipe._pending_cv, lock_witness.WitnessLock)
        with pytest.raises(RuntimeError, match="already installed"):
            lock_witness.install()
    finally:
        lock_witness.uninstall(session)
    fresh = UpdatePipe(object())
    assert not isinstance(fresh._ingest_lock, lock_witness.WitnessLock)
    # a wrapped condition still delegates wait/notify to the primitive
    with pipe._pending_cv:
        assert pipe._pending_cv.wait_for(lambda: True, timeout=0.1)


def test_declared_order_tables_are_consistent():
    # every attr/class mapping resolves to a ranked qualified name
    for qual in lock_order.ATTR_LOCKS.values():
        assert lock_order.rank_of(qual) is not None, qual
    for qual in lock_order.CLASS_LOCKS.values():
        assert lock_order.rank_of(qual) is not None, qual
    # every documented nesting is rank-increasing
    for outer, inner, _why in lock_order.OBSERVED_NESTINGS:
        assert lock_order.rank_of(outer) < lock_order.rank_of(inner), (
            outer, inner)
