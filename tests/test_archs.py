"""Per-architecture smoke tests (deliverable f).

Each assigned architecture instantiates a REDUCED variant of the same family
(2 layers, d_model <= 512, <= 4 experts) and runs one forward + one train
step on CPU, asserting output shapes and the absence of NaNs. Decode-match
tests prove the serving path (KV caches, SSM states, MLA absorbed decode)
agrees with the full forward.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.models import registry
from repro.optim import make_optimizer
from repro.train.steps import make_serve_step, make_train_step

ARCHS = registry.ARCH_IDS


def _batch(cfg, key, B=2, S=16):
    batch = {
        "tokens": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
        "labels": jax.random.randint(key, (B, S), 0, cfg.vocab_size),
    }
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_shapes_no_nan(arch):
    cfg = registry.get_config(arch, smoke=True)
    assert cfg.n_layers <= 8 and cfg.d_model <= 512
    if cfg.is_moe:
        assert cfg.n_experts <= 4
    key = jax.random.PRNGKey(0)
    params = registry.init_params(cfg, key)
    B, S = 2, 16
    logits, aux = registry.forward(cfg, params, _batch(cfg, key, B, S))
    assert logits.shape == (B, S, cfg.padded_vocab)
    assert not bool(jnp.isnan(logits).any())
    assert not bool(jnp.isnan(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    cfg = registry.get_config(arch, smoke=True)
    key = jax.random.PRNGKey(1)
    params = registry.init_params(cfg, key)
    opt = make_optimizer("adam", lr=1e-3)
    step_fn = jax.jit(make_train_step(cfg, opt))
    state = opt.init(params)
    batch = _batch(cfg, key)
    step = jnp.zeros((), jnp.int32)
    losses = []
    for _ in range(3):
        params, state, step, m = step_fn(params, state, step, batch)
        losses.append(float(m["loss"]))
    assert all(not jnp.isnan(l) for l in jnp.asarray(losses))
    assert losses[-1] < losses[0], f"{arch}: loss did not decrease: {losses}"


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(arch):
    cfg = registry.get_config(arch, smoke=True)
    key = jax.random.PRNGKey(2)
    params = registry.init_params(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    kw = {}
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(key, (B, S, cfg.d_model), jnp.float32)
        kw["src_len"] = S
    full, _ = registry.forward(cfg, params, batch)

    state = registry.init_decode_state(cfg, B, S, **kw)
    if cfg.family == "encdec":
        from repro.models import encdec

        state = encdec.prefill_cross(cfg, params, state, batch["frames"])
    outs = []
    for i in range(S):
        lg, state = registry.decode_step(cfg, params, state, toks[:, i])
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(dec - full))) / (float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 5e-3, f"{arch}: decode/forward mismatch rel={rel}"


@pytest.mark.parametrize("arch", ["llama3.2-1b", "zamba2-7b"])
def test_windowed_decode_ring_buffer(arch):
    """With window >= S the ring buffer must agree with the full cache."""
    cfg = registry.get_config(arch, smoke=True)
    key = jax.random.PRNGKey(3)
    params = registry.init_params(cfg, key)
    B, S, W = 2, 10, 16
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)

    full_state = registry.init_decode_state(cfg, B, S)
    ring_state = registry.init_decode_state(cfg, B, S + W, window=W)
    for i in range(S):
        lf, full_state = registry.decode_step(cfg, params, full_state, toks[:, i])
        lr_, ring_state = registry.decode_step(
            cfg, params, ring_state, toks[:, i], window=W
        )
        rel = float(jnp.max(jnp.abs(lf - lr_))) / (float(jnp.max(jnp.abs(lf))) + 1e-9)
        assert rel < 5e-3, f"{arch} step {i}: ring/full mismatch {rel}"


def test_serve_step_greedy():
    cfg = registry.get_config("llama3.2-1b", smoke=True)
    key = jax.random.PRNGKey(4)
    params = registry.init_params(cfg, key)
    serve = jax.jit(make_serve_step(cfg))
    state = registry.init_decode_state(cfg, 2, 8)
    tok = jnp.zeros((2,), jnp.int32)
    for _ in range(4):
        tok, state = serve(params, state, tok)
    assert tok.shape == (2,)
    assert int(state["pos"]) == 4


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_matches_specs(arch):
    """The analytic count (roofline MODEL_FLOPS) must match the spec tree."""
    from repro.common import pspec

    cfg = registry.get_config(arch)
    analytic = cfg.param_count()
    true = pspec.count(registry.param_specs(cfg))
    assert abs(analytic - true) / true < 0.02, (arch, analytic, true)


@pytest.mark.parametrize("arch", ["llama3.2-1b", "qwen2.5-3b"])
def test_int8_kv_cache_decode(arch):
    """Quantized KV cache (paper §6 applied to serving): small bounded error."""
    cfg = registry.get_config(arch, smoke=True).replace(kv_cache_dtype="int8")
    key = jax.random.PRNGKey(5)
    params = registry.init_params(cfg, key)
    B, S = 2, 12
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    full, _ = registry.forward(cfg, params, {"tokens": toks})
    state = registry.init_decode_state(cfg, B, S)
    outs = []
    for i in range(S):
        lg, state = registry.decode_step(cfg, params, state, toks[:, i])
        outs.append(lg)
    dec = jnp.stack(outs, 1)
    rel = float(jnp.max(jnp.abs(dec - full))) / (float(jnp.max(jnp.abs(full))) + 1e-9)
    assert rel < 0.05, f"{arch}: int8-cache decode error {rel}"
    assert state["cache"]["k"].dtype == jnp.int8
