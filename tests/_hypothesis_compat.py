"""Import hypothesis if available; otherwise provide stubs so that modules
using ``@given`` still collect — the property tests skip, the plain pytest
tests in the same files keep running."""
import pytest

try:
    from hypothesis import given, settings, strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    class _AnyStrategy:
        """Absorbs any strategy construction (st.lists(st.integers(...), ...))."""

        def __getattr__(self, name):
            return self

        def __call__(self, *args, **kwargs):
            return self

    st = _AnyStrategy()

    def given(*args, **kwargs):
        return pytest.mark.skip(reason="hypothesis not installed")

    def settings(*args, **kwargs):
        def deco(fn):
            return fn

        return deco
