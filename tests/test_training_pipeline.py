"""The unified online-training pipeline (PR 3): jitted sparse-backward round
step, row-delta update frames, and async hot-swap ingestion — the full
train->serve loop against a from-scratch forward oracle."""
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import layout, transfer
from repro.common.config import FFMConfig
from repro.core import deepffm
from repro.data.synthetic import CTRStream
from repro.optim import make_optimizer
from repro.serving.engine import InferenceEngine
from repro.train.loop import OnlineTrainer
from repro.train.pipeline import (TrainingPipeline, make_round_step,
                                  make_sparse_round_step, touched_paths)

pytestmark = pytest.mark.tier1

CFG = FFMConfig(n_fields=8, context_fields=4, hash_space=2**12, k=4,
                mlp_hidden=(16,))


def _stack(batches):
    return {k: np.stack([b[k] for b in batches]) for k in batches[0]}


# ---------------------------------------------------------------------------
# Trainer layer
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("model", ["linear", "mlp", "ffm", "deepffm"])
def test_sparse_round_step_matches_dense(model):
    """The O(batch) gather/scatter AdaGrad step is the dense full-space step
    restricted to the touched rows — params, acc, and pre-update scores all
    agree (duplicate feature occurrences included)."""
    opt = make_optimizer("adagrad", lr=0.1)
    stream = CTRStream(CFG, seed=1)
    stacked = _stack([stream.sample(32) for _ in range(4)])
    results = {}
    for name, maker in (("dense", make_round_step),
                        ("sparse", make_sparse_round_step)):
        params = deepffm.init_params(CFG, jax.random.PRNGKey(0), model)
        state = opt.init(params)
        rf = maker(CFG, model, opt, donate=False)
        results[name] = rf(params, state, jnp.zeros((), jnp.int32), stacked)
    for a, b in zip(jax.tree_util.tree_leaves(results["dense"][:2]),
                    jax.tree_util.tree_leaves(results["sparse"][:2])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=2e-4, atol=1e-6)
    np.testing.assert_allclose(np.asarray(results["dense"][3]["scores"]),
                               np.asarray(results["sparse"][3]["scores"]),
                               rtol=1e-4, atol=1e-6)


def test_sparse_backward_grads_equal_autodiff_on_deepffm():
    """§4.3 on by default: DeepFFM's MLP routed through ``relu_linear`` must
    produce the same gradients as the plain autodiff oracle."""
    params = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    params["mlp"]["w1"] = jax.random.normal(jax.random.PRNGKey(1),
                                            params["mlp"]["w1"].shape) * 0.3
    batch = CTRStream(CFG, seed=2).sample(64)
    gs = jax.grad(lambda p: deepffm.loss_fn(CFG, p, batch,
                                            sparse_backward=True))(params)
    gd = jax.grad(lambda p: deepffm.loss_fn(CFG, p, batch,
                                            sparse_backward=False))(params)
    for a, b in zip(jax.tree_util.tree_leaves(gs),
                    jax.tree_util.tree_leaves(gd)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-5, atol=1e-6)


def test_round_report_and_frame_version_agree():
    """The PR 3 off-by-one fix: ``RoundReport.round`` == the frame stamp."""
    stream = CTRStream(CFG, seed=3)
    trainer = OnlineTrainer(CFG, lr=0.1)
    for expect in (1, 2):
        update = trainer.run_round(stream.batches(64, 3))
        frame = transfer.unframe(update)
        assert trainer.reports[-1].round == frame.version == expect


def test_skip_stats_surface_in_round_report():
    pl = TrainingPipeline(CFG, lr=0.1)
    pl.run_round(CTRStream(CFG, seed=4).batches(64, 3))
    rep = pl.reports[-1]
    assert set(rep.skip_stats) >= {"unit_skip_frac", "tile_skip_frac",
                                   "modeled_update_speedup"}
    assert 0.0 <= rep.skip_stats["unit_skip_frac"] <= 1.0
    assert rep.touched_rows > 0 and rep.examples_per_s > 0


def test_local_sgd_workers_must_be_power_of_two():
    """Averaging W identical untouched rows is bit-exact only for 2^k workers
    — the row-delta frames rely on untouched rows staying byte-stable."""
    with pytest.raises(ValueError, match="power of two"):
        TrainingPipeline(CFG, backend="local_sgd", local_sgd_workers=3)


# ---------------------------------------------------------------------------
# Transfer layer
# ---------------------------------------------------------------------------

def _drift_rows(params, rows):
    p = jax.tree_util.tree_map(lambda x: np.array(x, np.float32), params)
    p["ffm"]["emb"][rows] += 0.01
    p["lr"]["w"][rows] -= 0.01
    p["mlp"]["w0"] += 0.001  # dense leaves always change
    return jax.tree_util.tree_map(jnp.asarray, p)


@pytest.mark.parametrize("mode", transfer.MODES)
def test_delta_frame_roundtrip_byte_exact(mode):
    """KIND_DELTA reconstructs the receiver buffer byte-for-byte in every
    mode (``delta_verify`` additionally scans for changes the touched set
    would have missed)."""
    p0 = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    rows = np.array([1, 57, 1033, 4000])
    p1 = _drift_rows(p0, rows)
    snd = transfer.Sender(mode=mode, delta_verify=True)
    rcv = transfer.Receiver()
    rcv.apply_update(snd.make_update(p0))
    update = snd.make_update(p1, touched={"ffm/emb": rows, "lr/w": rows})
    assert transfer.unframe(update).is_delta
    rcv.apply_update(update)
    assert rcv._current == snd._last  # byte-identical server state
    got = rcv.materialize(mode, snd.manifest, like=p1)
    for (_, a), (_, b) in zip(layout.flatten_with_paths(p1),
                              layout.flatten_with_paths(got)):
        if "quant" in mode:
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=5e-4)
        else:
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_multiple_deltas_between_materialize_calls():
    """The receiver's incremental dequantize must cover the union of every
    delta applied since the last materialize — streaming several frames and
    materializing once is the classic Receiver usage."""
    p0 = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    rows1, rows2 = np.array([5, 900]), np.array([42, 2222])
    p1 = _drift_rows(p0, rows1)
    p2 = _drift_rows(p1, rows2)
    snd = transfer.Sender(mode="patch+quant", delta_verify=True)
    rcv = transfer.Receiver()
    rcv.apply_update(snd.make_update(p0))
    rcv.materialize("patch+quant", snd.manifest)  # arms the incremental path
    all_rows = np.concatenate([rows1, rows2])
    rcv.apply_update(snd.make_update(
        p1, touched={"ffm/emb": rows1, "lr/w": rows1}))
    rcv.apply_update(snd.make_update(
        p2, touched={"ffm/emb": all_rows, "lr/w": all_rows}))
    got = rcv.materialize("patch+quant", snd.manifest, like=p2)
    for (_, a), (_, b) in zip(layout.flatten_with_paths(p2),
                              layout.flatten_with_paths(got)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=5e-4)


def test_sync_ingest_never_overtakes_queued_frames():
    """apply_update while frames sit in the submit queue must drain them
    first — a sync frame applied against the wrong base bytes would silently
    corrupt the patch/delta chain."""
    stream = CTRStream(CFG, seed=11)
    pl = TrainingPipeline(CFG, lr=0.1, delta_updates=True)
    engine = InferenceEngine(CFG)
    updates = [pl.run_round(stream.batches(64, 2)) for _ in range(4)]
    engine.apply_update(updates[0], pl.sender.manifest, pl.params)
    engine.submit_update(updates[1])
    engine.submit_update(updates[2])
    engine.apply_update(updates[3])  # must land after 1 and 2
    assert engine.weights_version == 4 and engine.generation == 4
    ci, cv, ki, kv = stream.request(4)
    np.testing.assert_allclose(np.asarray(engine.score(ci, cv, ki, kv)),
                               _oracle(engine, ci, cv, ki, kv),
                               rtol=2e-4, atol=2e-5)
    engine.update_pipe().close()


def test_delta_verify_catches_incomplete_touched_set():
    p0 = deepffm.init_params(CFG, jax.random.PRNGKey(0))
    rows = np.array([3, 99])
    p1 = _drift_rows(p0, np.array([3, 99, 2048]))  # 2048 changes too
    snd = transfer.Sender(mode="raw", delta_verify=True)
    snd.make_update(p0)
    with pytest.raises(ValueError, match="outside the touched rows"):
        snd.make_update(p1, touched={"ffm/emb": rows, "lr/w": rows})


def test_pipeline_emits_delta_frames_in_steady_state():
    pl = TrainingPipeline(CFG, lr=0.1, delta_updates=True)
    stream = CTRStream(CFG, seed=5)
    kinds = []
    for _ in range(3):
        update = pl.run_round(stream.batches(64, 3))
        kinds.append(transfer.unframe(update).kind)
    assert kinds[0] == transfer.KIND_FULL           # nothing to delta against
    assert set(kinds[1:]) == {transfer.KIND_DELTA}  # steady state
    assert kinds == [
        {"full": transfer.KIND_FULL, "patch": transfer.KIND_PATCH,
         "delta": transfer.KIND_DELTA}[r.update_kind] for r in pl.reports]


# ---------------------------------------------------------------------------
# The full train -> serve round trip
# ---------------------------------------------------------------------------

def _oracle(engine, ci, cv, ki, kv):
    n = ki.shape[0]
    fc = CFG.context_fields
    idx = np.concatenate([np.broadcast_to(ci, (n, fc)), ki], axis=1)
    val = np.concatenate([np.broadcast_to(cv, (n, fc)), kv], axis=1)
    return np.asarray(deepffm.forward(CFG, engine.params, idx, val,
                                      engine.model))


@pytest.mark.parametrize("mode", transfer.MODES)
def test_train_serve_roundtrip(mode):
    """N trainer rounds piped through every transfer mode (+ row deltas) into
    the engine: at each generation the engine's scores equal a from-scratch
    ``deepffm.forward`` on the engine's params, and those params match the
    trainer's within the mode's tolerance."""
    stream = CTRStream(CFG, seed=6)
    pl = TrainingPipeline(CFG, lr=0.1, transfer_mode=mode, delta_updates=True)
    engine = InferenceEngine(CFG)
    for rnd in range(1, 4):
        update = pl.run_round(stream.batches(64, 4))
        engine.apply_update(update, pl.sender.manifest, pl.params)
        assert engine.generation == rnd
        assert engine.weights_version == pl.reports[-1].round == rnd
        ci, cv, ki, kv = stream.request(5)
        got = np.asarray(engine.score(ci, cv, ki, kv))
        np.testing.assert_allclose(got, _oracle(engine, ci, cv, ki, kv),
                                   rtol=2e-4, atol=2e-5)
        tol = 5e-4 if "quant" in mode else 1e-7
        for a, b in zip(jax.tree_util.tree_leaves(pl.params),
                        jax.tree_util.tree_leaves(engine.params)):
            np.testing.assert_allclose(np.asarray(a, np.float32),
                                       np.asarray(b, np.float32), atol=tol)
    assert pl.reports[-1].update_kind == "delta"  # steady state, every mode


@pytest.mark.parametrize("backend", ["hogwild", "local_sgd"])
def test_alternate_backends_through_the_same_pipe(backend):
    """Hogwild / local-SGD rounds produce finite losses and valid frames that
    flow through the identical transfer+engine pipe."""
    stream = CTRStream(CFG, seed=7)
    pl = TrainingPipeline(CFG, backend=backend, lr=0.05, delta_updates=True)
    engine = InferenceEngine(CFG)
    for _ in range(2):
        update = pl.run_round(stream.batches(64, 4))
        engine.apply_update(update, pl.sender.manifest, pl.params)
    rep = pl.reports[-1]
    assert np.isfinite(rep.mean_loss) and rep.examples > 0
    assert engine.generation == 2 and engine.weights_version == 2
    ci, cv, ki, kv = stream.request(4)
    got = np.asarray(engine.score(ci, cv, ki, kv))
    assert np.isfinite(got).all()
    np.testing.assert_allclose(got, _oracle(engine, ci, cv, ki, kv),
                               rtol=2e-4, atol=2e-5)


# ---------------------------------------------------------------------------
# Async ingestion
# ---------------------------------------------------------------------------

def test_async_update_pipe_publishes_in_order():
    stream = CTRStream(CFG, seed=8)
    pl = TrainingPipeline(CFG, lr=0.1, delta_updates=True)
    engine = InferenceEngine(CFG)
    updates = [pl.run_round(stream.batches(64, 2)) for _ in range(4)]
    for u in updates:
        assert engine.submit_update(u, pl.sender.manifest, pl.params)
    assert engine.update_pipe().flush()  # True: drained, not killed
    assert engine.generation == 4
    assert engine.weights_version == 4  # frames applied FIFO
    assert engine.update_pipe().stats.published == 4
    ci, cv, ki, kv = stream.request(5)
    np.testing.assert_allclose(np.asarray(engine.score(ci, cv, ki, kv)),
                               _oracle(engine, ci, cv, ki, kv),
                               rtol=2e-4, atol=2e-5)
    engine.update_pipe().close()


def test_scoring_concurrent_with_async_ingest_never_tears():
    """Scores taken while the pipe ingests in the background always match the
    oracle for *some* published generation — never a mix.

    The oracle score set is precomputed by replaying the identical update
    chain through a reference engine, one sync apply per generation."""
    stream = CTRStream(CFG, seed=9)
    pl = TrainingPipeline(CFG, "ffm", lr=0.1, delta_updates=True)
    updates = [pl.run_round(stream.batches(64, 2)) for _ in range(5)]
    ci, cv, ki, kv = stream.request(6)

    ref = InferenceEngine(CFG, "ffm")
    valid = []
    for u in updates:
        ref.apply_update(u, pl.sender.manifest, pl.params)
        valid.append(_oracle(ref, ci, cv, ki, kv))

    engine = InferenceEngine(CFG, "ffm")
    engine.apply_update(updates[0], pl.sender.manifest, pl.params)
    engine.warmup(max_requests=1, max_candidates=8)

    errors = []

    def scorer():
        for _ in range(60):
            got = np.asarray(engine.score(ci, cv, ki, kv))
            if not any(np.allclose(got, want, rtol=2e-4, atol=2e-5)
                       for want in valid):
                errors.append(got)

    t = threading.Thread(target=scorer)
    t.start()
    for u in updates[1:]:
        engine.submit_update(u, pl.sender.manifest, pl.params)
    engine.update_pipe().flush()
    t.join()
    engine.update_pipe().close()
    assert not errors
    assert engine.generation == len(updates)


def test_sync_apply_update_still_works_without_thread():
    """The thin wrapper never spawns a thread for synchronous use."""
    stream = CTRStream(CFG, seed=10)
    pl = TrainingPipeline(CFG, lr=0.1)
    engine = InferenceEngine(CFG)
    engine.apply_update(pl.run_round(stream.batches(64, 2)),
                        pl.sender.manifest, pl.params)
    assert engine.update_pipe()._thread is None
    assert engine.generation == 1
