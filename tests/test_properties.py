"""Extra property-based tests on system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from tests._hypothesis_compat import given, settings, st

from repro.common.config import FFMConfig
from repro.core import deepffm
from repro.launch import hlo_analysis
from repro.serving.context_cache import CachedServer


@given(
    n_fields=st.integers(4, 16),
    ctx_frac=st.floats(0.2, 0.8),
    k=st.sampled_from([2, 4, 8]),
    n_cand=st.integers(1, 9),
    seed=st.integers(0, 10_000),
)
@settings(max_examples=25, deadline=None)
def test_context_cache_equivalence_any_config(n_fields, ctx_frac, k, n_cand, seed):
    """Cached context/candidate decomposition == full forward, any field split."""
    fc = max(1, min(n_fields - 1, int(n_fields * ctx_frac)))
    cfg = FFMConfig(n_fields=n_fields, context_fields=fc, hash_space=2**10, k=k,
                    mlp_hidden=(8,))
    rng = np.random.default_rng(seed)
    params = deepffm.init_params(cfg, jax.random.PRNGKey(seed % 97))
    params["lr"]["w"] = jnp.asarray(rng.normal(0, 0.1, cfg.hash_space), jnp.float32)
    srv = CachedServer(cfg, params)
    ci = rng.integers(0, cfg.hash_space, fc).astype(np.int32)
    cv = rng.normal(1, 0.2, fc).astype(np.float32)
    ki = rng.integers(0, cfg.hash_space, (n_cand, n_fields - fc)).astype(np.int32)
    kv = rng.normal(1, 0.2, (n_cand, n_fields - fc)).astype(np.float32)
    a = np.asarray(srv.serve(ci, cv, ki, kv))
    b = np.asarray(srv.serve_uncached(ci, cv, ki, kv))
    np.testing.assert_allclose(a, b, rtol=5e-4, atol=5e-4)


@given(trips=st.integers(1, 6), inner=st.integers(1, 5),
       m=st.sampled_from([8, 16, 32]))
@settings(max_examples=15, deadline=None)
def test_hlo_analyzer_nested_scan_flops(trips, inner, m):
    """Nested scan trip counts multiply through the analyzer's call walk."""
    def g(x, ws):
        def outer(x, w):
            def inner_body(x, _):
                return jnp.tanh(x @ w), None
            x, _ = jax.lax.scan(inner_body, x, None, length=inner)
            return x, None
        return jax.lax.scan(outer, x, ws)[0]

    c = jax.jit(g).lower(
        jax.ShapeDtypeStruct((m, m), jnp.float32),
        jax.ShapeDtypeStruct((trips, m, m), jnp.float32),
    ).compile()
    r = hlo_analysis.analyze(c.as_text())
    want = trips * inner * 2 * m * m * m
    assert r["flops_per_device"] == pytest.approx(want, rel=0.05), (
        r["flops_per_device"], want)


@given(st.integers(1, 64), st.integers(1, 64), st.integers(0, 3))
@settings(max_examples=20, deadline=None)
def test_quantization_hysteresis_keeps_grid(n_big, n_small, seed):
    """Sub-threshold outliers never regrid; codes for unchanged weights stay."""
    from repro.core import quantization as Q

    rng = np.random.default_rng(seed)
    w0 = rng.normal(0, 0.1, 100_000).astype(np.float32)
    q0, m0, _ = Q.quantize(jnp.asarray(w0))
    w1 = w0.copy()
    idx = rng.choice(w1.size, n_big, replace=False)
    w1[idx] += 10.0  # way outside the grid -> outliers
    q1, m1, out = Q.quantize(jnp.asarray(w1), prev=m0)
    assert (m1.w_min, m1.bucket_size) == (m0.w_min, m0.bucket_size)
    assert m1.n_outliers == n_big
    wd = np.asarray(Q.dequantize(q1.copy(), m1, out))
    np.testing.assert_allclose(wd[idx], w1[idx], atol=1e-6)  # outliers exact
    untouched = np.setdiff1d(np.arange(w1.size), idx)[:1000]
    assert (np.asarray(q1)[untouched] == np.asarray(q0)[untouched]).all()
