"""Fused bucket scoring (one Pallas call per microbatch) end-to-end.

The fused path rewrites the staged serving forward — context-tail extend,
candidate pair matrices, pair-vector head — into a single kernel launch per
padding bucket with int8 pair arithmetic. The staged path stays in the tree
as the oracle; everything here pins the fused path to it:

* parity across *every* warmup bucket (ragged request/candidate counts,
  partial-depth prefix hits, empty slates), quantized and f32, inside the
  derived ``fused_logit_tolerance`` (the only new error is f32 summation
  reassociation plus the affine int8 pair decomposition);
* the prefix cache still *learns* through fused scoring: the kernel's
  ctx-dots readback inserts full-depth states, so repeat traffic full-hits;
* auto-selection: fused rides the auto host-gather policy and never flips
  an engine whose strategy was pinned by the caller;
* the sharded fleet keeps its bit-invariance contract (shards never
  auto-fuse — their partial-sum reduction order is the contract);
* scoring stays atomic while delta frames stream into the quantized tables;
* the two hot-path bugfixes riding this PR: ``ServeStats`` latency
  recording is bounded + thread-safe, and the gather-cliff calibration
  probe runs exactly once under a thread race;
* the parallel scoring pipeline (``parallel=N``): bit-parity with the
  single-stream engine for every worker count and forward path, parity
  held at every generation while concurrent callers race streaming delta
  ingest (no torn ``(params, generation)`` snapshots), span planning /
  buffer recycling mechanics, and stats recorded once per caller-visible
  batch regardless of chunk splitting.
"""
import threading
import time

import jax
import numpy as np
import pytest

from repro.checkpoint import transfer
from repro.common.config import FFMConfig
from repro.core import deepffm
from repro.core import quantization as Q
from repro.serving.engine import InferenceEngine, ScoringPool, ServeStats

CFG = FFMConfig(n_fields=12, context_fields=8, hash_space=2**13, k=4,
                mlp_hidden=(16,))
FC, FCAND = CFG.context_fields, CFG.n_fields - CFG.context_fields


def _params(seed=0):
    params = deepffm.init_params(CFG, jax.random.PRNGKey(seed), "ffm")
    params["lr"]["w"] = np.asarray(jax.random.normal(
        jax.random.PRNGKey(seed + 1), params["lr"]["w"].shape)) * 0.1
    return jax.tree_util.tree_map(np.asarray, params)


def _req(rng, n_cand, ctx=None):
    ci, cv = ctx if ctx is not None else (
        rng.integers(0, CFG.hash_space, FC).astype(np.int32),
        rng.normal(1, 0.25, FC).astype(np.float32))
    return (ci, cv,
            rng.integers(0, CFG.hash_space, (n_cand, FCAND)).astype(np.int32),
            rng.normal(1, 0.25, (n_cand, FCAND)).astype(np.float32))


def _engine(params, *, quantized, fused, **kw):
    return InferenceEngine(CFG, "ffm", backend="pallas", params=params,
                           prefix_stride=4, quantized=quantized,
                           host_gather=True, fused=fused,
                           warmup_buckets=(8, 32), **kw)


def _tolerances(params, engine, reqs):
    vmax = float(max(max(np.abs(r[1]).max(), np.abs(r[3]).max())
                     for r in reqs))
    absmax = float(np.abs(params["ffm"]["emb"]).max())
    if engine.quantized:
        eps = Q.row_max_error(engine.params["ffm"]["emb"])
    else:
        eps = 0.0  # f32 rows: the bound collapses to pure reassociation
    return Q.fused_logit_tolerance(CFG, absmax, eps, vmax=vmax)


@pytest.mark.parametrize("quantized", [True, False])
def test_fused_matches_staged_across_all_warmup_buckets(quantized):
    """Every (request, candidate) bucket the warmed engine can emit —
    ragged sizes, shared contexts (prefix hits at partial depth), and an
    empty slate mixed in — scores within the derived tolerance of the
    staged path on the same tables."""
    params = _params()
    staged = _engine(params, quantized=quantized, fused=False)
    fused = _engine(params, quantized=quantized, fused=True)
    assert fused.fused and not staged.fused
    rng = np.random.default_rng(7)
    hot = (rng.integers(0, CFG.hash_space, FC).astype(np.int32),
           rng.normal(1, 0.25, FC).astype(np.float32))
    batches = []
    for n_req, n_cand in [(1, 1), (1, 5), (2, 8), (3, 17), (8, 32), (5, 9)]:
        reqs = [_req(rng, n_cand, ctx=hot if s % 2 else None)
                for s in range(n_req)]
        batches.append(reqs)
    batches.append([_req(rng, 4),
                    (hot[0], hot[1],
                     np.zeros((0, FCAND), np.int32),
                     np.zeros((0, FCAND), np.float32))])
    for reqs in batches:
        tol = _tolerances(params, fused, [r for r in reqs if r[2].size])
        want = staged.score_batch(reqs)
        got = fused.score_batch(reqs)
        for w, g in zip(want, got):
            assert np.asarray(g).shape == np.asarray(w).shape
            if np.asarray(w).size:
                dev = float(np.max(np.abs(np.asarray(g) - np.asarray(w))))
                assert dev <= tol, (dev, tol, len(reqs))


def test_fused_prefix_cache_learns_and_full_hits():
    """The ctx-dots readback must insert *full-depth* states: the second
    pass over identical contexts full-hits (depth == context_fields) and
    still matches the staged oracle — the rebuilt pair vectors are real."""
    params = _params(3)
    fused = _engine(params, quantized=True, fused=True)
    staged = _engine(params, quantized=True, fused=False)
    rng = np.random.default_rng(11)
    ctxs = [(rng.integers(0, CFG.hash_space, FC).astype(np.int32),
             rng.normal(1, 0.25, FC).astype(np.float32)) for _ in range(4)]
    first = [_req(rng, 16, ctx=c) for c in ctxs]
    second = [_req(rng, 16, ctx=c) for c in ctxs]  # same ctx, fresh slates
    fused.score_batch(first)
    fused.prefix_hit_depths.clear()
    got = fused.score_batch(second)
    assert fused.prefix_hit_depths == {FC: len(ctxs)}
    staged.score_batch(first)
    want = staged.score_batch(second)
    tol = _tolerances(params, fused, second)
    for w, g in zip(want, got):
        assert float(np.max(np.abs(np.asarray(g) - np.asarray(w)))) <= tol


def test_fused_auto_selection_respects_pinned_strategies():
    """Auto-fused activates only where the host-gather policy itself was
    auto: pinning ``host_gather`` (either way) or a non-ffm head keeps the
    engine staged, and ``fused=True`` on a non-ffm head refuses loudly."""
    from repro.kernels.row_gather import ops as rg_ops

    params = _params()
    # pinned host_gather=True: the dedup-vs-in-trace bit-compat contract
    assert not InferenceEngine(CFG, "ffm", params=params, quantized=True,
                               host_gather=True).fused
    assert not InferenceEngine(CFG, "ffm", params=params, quantized=True,
                               host_gather=False).fused
    # auto host gather: fused iff the policy picks the host path
    auto = InferenceEngine(CFG, "ffm", params=params, quantized=True)
    assert auto.fused == auto.host_gather == rg_ops.use_host_gather(
        CFG.hash_space)
    # f32 engines and deepffm heads never auto-fuse
    assert not InferenceEngine(CFG, "ffm", params=params).fused
    deep = deepffm.init_params(CFG, jax.random.PRNGKey(0), "deepffm")
    assert not InferenceEngine(CFG, params=deep, quantized=True).fused
    with pytest.raises(ValueError):
        InferenceEngine(CFG, params=deep, quantized=True, fused=True)
    # explicit fused forces the host pre-gather it depends on
    forced = InferenceEngine(CFG, "ffm", params=params, quantized=True,
                             fused=True)
    assert forced.fused and forced.host_gather


def test_fused_single_engine_vs_shard_router():
    """The sharded fleet's scores are bit-invariant across shard counts
    (its fixed-order reduction contract — shards must never auto-fuse) and
    the fused single engine stays within tolerance of the fleet."""
    from repro.serving.shard_router import ShardRouter

    params = _params(5)
    fused = _engine(params, quantized=True, fused=True)
    routers = {n: ShardRouter(CFG, "ffm", n_shards=n, params=params,
                              quantized=True, prefix_stride=4)
               for n in (1, 2)}
    for r in routers.values():
        assert not r.fused
        assert all(not s.fused for s in r.shards)
    rng = np.random.default_rng(13)
    batches = [[_req(rng, 12) for _ in range(3)] for _ in range(2)]
    outs = {}
    for n, r in routers.items():
        outs[n] = np.concatenate(
            [np.concatenate([np.asarray(o) for o in r.score_batch(reqs)])
             for reqs in batches])
    np.testing.assert_array_equal(outs[1], outs[2])
    got = np.concatenate(
        [np.concatenate([np.asarray(o) for o in fused.score_batch(reqs)])
         for reqs in batches])
    tol = _tolerances(params, fused, [r for reqs in batches for r in reqs])
    # the fleet re-sums xc pair terms across shards in its own fixed order;
    # give the cross-arm comparison that reassociation headroom on top
    assert float(np.max(np.abs(got - outs[1]))) <= tol + 1e-5
    for r in routers.values():
        r.close()


def test_fused_scoring_while_deltas_stream():
    """Scorer threads race async delta ingest through the *fused* engine:
    every batch's scores come from exactly one published generation (zero
    emb rows quantize exactly, so any valid score is exactly v * n_fields),
    and after the stream settles the fused scores still match the staged
    oracle on the final tables."""
    versions = [float(3 ** i) for i in range(4)]

    def params_v(v):
        p = deepffm.init_params(CFG, jax.random.PRNGKey(0), "ffm")
        p = jax.tree_util.tree_map(lambda x: np.zeros_like(x), p)
        p["lr"]["w"] = np.full_like(p["lr"]["w"], v)
        return p

    eng = InferenceEngine(CFG, "ffm", quantized=True, fused=True,
                          params=params_v(versions[0]),
                          warmup_buckets=(4, 8))
    assert eng.fused
    snd = transfer.Sender(mode="raw")
    updates = [snd.make_update(params_v(v)) for v in versions]
    eng.update_pipe(snd.manifest, params_v(0.0))
    valid = {round(v * CFG.n_fields, 3) for v in versions}
    errors, stop = [], threading.Event()

    def scorer(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            reqs = []
            for _ in range(rng.integers(1, 4)):
                ci = rng.integers(0, CFG.hash_space, FC).astype(np.int32)
                ki = rng.integers(0, CFG.hash_space,
                                  (rng.integers(1, 5), FCAND)).astype(np.int32)
                reqs.append((ci, np.ones(FC, np.float32), ki,
                             np.ones(ki.shape, np.float32)))
            outs = eng.score_batch(reqs)
            got = {round(float(x), 3) for o in outs for x in np.asarray(o)}
            if not got <= valid:
                errors.append(got - valid)
            if len(got) > 1:  # one snapshot per batch -> one version per batch
                errors.append(got)

    threads = [threading.Thread(target=scorer, args=(s,)) for s in (1, 2)]
    for t in threads:
        t.start()
    for u in updates[1:]:
        time.sleep(0.03)
        eng.submit_update(u)
    eng.update_pipe().flush()
    time.sleep(0.03)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors[:5]
    assert eng.generation == len(versions) - 1
    # settled-state parity vs the engine's own staged full forward
    rng = np.random.default_rng(17)
    req = _req(rng, 8)
    got = np.asarray(eng.score(*req))
    want = np.asarray(eng.score_uncached(*req))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-5)


def test_serve_stats_record_is_bounded_and_thread_safe():
    """The latency reservoir is a bounded deque: concurrent recorders never
    lose counter increments to a list-append race beyond the window, and
    percentile snapshots taken *during* recording never crash."""
    stats = ServeStats(latency_window=256)
    n_threads, n_each = 8, 500
    crashed = []

    def recorder(seed):
        rng = np.random.default_rng(seed)
        for _ in range(n_each):
            stats.record(float(rng.uniform(1e-4, 1e-2)), 4)

    def reader():
        for _ in range(200):
            try:
                stats.p50_ms, stats.p99_ms  # noqa: B018 - exercised for races
            except Exception as e:  # pragma: no cover - the regression
                crashed.append(e)

    threads = ([threading.Thread(target=recorder, args=(s,))
                for s in range(n_threads)]
               + [threading.Thread(target=reader)])
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not crashed
    assert stats.requests == n_threads * n_each
    assert stats.candidates == 4 * n_threads * n_each
    assert len(stats._latencies_s) == 256  # bounded, newest-window
    assert stats.p99_ms > 0


def test_cliff_calibration_probe_runs_once_under_race(monkeypatch):
    """N threads hitting their first gather concurrently must trigger
    exactly one calibration probe and agree on the result."""
    from repro.kernels.row_gather import ops as rg_ops

    calls = []

    def fake_probe():
        calls.append(1)
        time.sleep(0.02)  # widen the race window
        return 12345

    monkeypatch.setenv("REPRO_CLIFF_CALIBRATE", "1")
    monkeypatch.setattr(rg_ops, "_calibrated", None)
    monkeypatch.setattr(rg_ops, "calibrate_cliff_rows", fake_probe)
    results = []
    barrier = threading.Barrier(8)

    def hit():
        barrier.wait()
        results.append(rg_ops.cliff_rows())

    threads = [threading.Thread(target=hit) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert len(calls) == 1
    assert results == [12345] * 8


@pytest.mark.parametrize("quantized,fused",
                         [(True, True), (True, False), (False, False)])
def test_parallel_bit_parity_across_worker_counts(quantized, fused):
    """The parallel pipeline's contract: splitting a batch's chunks across
    workers must be *bit-identical* to the single-stream engine — per-chunk
    forwards are row-bucket-invariant and every span shares the batch's one
    resolved context snapshot, so the only thing parallelism may change is
    wall-clock. Ragged batches, shared contexts, and an empty slate all ride
    along; caches evolve identically across arms (fresh engines, same
    traffic)."""
    params = _params(9)
    outs = {}
    for workers in (1, 2, 4):
        eng = _engine(params, quantized=quantized, fused=fused,
                      parallel=workers)
        assert eng.parallel == workers
        rng = np.random.default_rng(19)  # identical traffic per arm
        hot = (rng.integers(0, CFG.hash_space, FC).astype(np.int32),
               rng.normal(1, 0.25, FC).astype(np.float32))
        batches = []
        for n_req, n_cand in [(1, 3), (3, 17), (8, 32), (5, 9)]:
            batches.append([_req(rng, n_cand, ctx=hot if s % 2 else None)
                            for s in range(n_req)])
        batches.append([_req(rng, 4),
                        (hot[0], hot[1],
                         np.zeros((0, FCAND), np.int32),
                         np.zeros((0, FCAND), np.float32))])
        outs[workers] = [np.asarray(o) for reqs in batches
                         for o in eng.score_batch(reqs)]
        eng.close()
    for workers in (2, 4):
        assert len(outs[workers]) == len(outs[1])
        for got, want in zip(outs[workers], outs[1]):
            np.testing.assert_array_equal(got, want)


def test_parallel_scoring_concurrent_callers_while_deltas_stream():
    """Concurrent ``score_batch`` callers x parallel workers x streaming
    delta ingest: every batch through the 4-worker engine still scores from
    exactly one published generation (no torn ``(params, generation)``
    snapshots across spans — zero emb rows quantize exactly, so a valid
    score is exactly v * n_fields), and at *every* generation the parallel
    engine is bit-identical to a single-stream engine fed the same update
    stream."""
    versions = [float(3 ** i) for i in range(4)]

    def params_v(v):
        p = deepffm.init_params(CFG, jax.random.PRNGKey(0), "ffm")
        p = jax.tree_util.tree_map(lambda x: np.zeros_like(x), p)
        p["lr"]["w"] = np.full_like(p["lr"]["w"], v)
        return p

    def make(parallel):
        eng = InferenceEngine(CFG, "ffm", quantized=True, fused=True,
                              params=params_v(versions[0]),
                              parallel=parallel, warmup_buckets=(4, 8))
        snd = transfer.Sender(mode="raw")
        updates = [snd.make_update(params_v(v)) for v in versions]
        eng.update_pipe(snd.manifest, params_v(0.0))
        return eng, updates

    par, par_updates = make(4)
    single, single_updates = make(1)
    assert par.fused and par.parallel == 4 and single.parallel == 1
    valid = {round(v * CFG.n_fields, 3) for v in versions}
    errors, stop = [], threading.Event()
    rng0 = np.random.default_rng(29)
    parity_reqs = [  # big enough to split across all 4 workers
        (rng0.integers(0, CFG.hash_space, FC).astype(np.int32),
         np.ones(FC, np.float32),
         rng0.integers(0, CFG.hash_space, (12, FCAND)).astype(np.int32),
         np.ones((12, FCAND), np.float32))
        for _ in range(6)]

    def scorer(seed):
        rng = np.random.default_rng(seed)
        while not stop.is_set():
            reqs = []
            for _ in range(rng.integers(2, 7)):
                ci = rng.integers(0, CFG.hash_space, FC).astype(np.int32)
                ki = rng.integers(0, CFG.hash_space,
                                  (rng.integers(1, 9), FCAND)).astype(np.int32)
                reqs.append((ci, np.ones(FC, np.float32), ki,
                             np.ones(ki.shape, np.float32)))
            outs = par.score_batch(reqs)
            got = {round(float(x), 3) for o in outs for x in np.asarray(o)}
            if not got <= valid:
                errors.append(got - valid)
            if len(got) > 1:  # one snapshot per batch -> one version per batch
                errors.append(got)

    threads = [threading.Thread(target=scorer, args=(s,)) for s in (1, 2)]
    for t in threads:
        t.start()
    for gen, (up, us) in enumerate(zip(par_updates, single_updates)):
        if gen:  # generation 0 is the construction-time params
            par.submit_update(up)
            single.submit_update(us)
            par.update_pipe().flush()
            single.update_pipe().flush()
        assert par.generation == single.generation
        # parity at this generation, while the scorer threads keep hammering
        want = single.score_batch(parity_reqs)
        got = par.score_batch(parity_reqs)
        for w, g in zip(want, got):
            np.testing.assert_array_equal(np.asarray(g), np.asarray(w))
        time.sleep(0.02)
    stop.set()
    for t in threads:
        t.join()
    assert not errors, errors[:5]
    assert par.generation == len(versions) - 1
    par.close()
    single.close()


def test_parallel_stats_record_once_per_caller_batch():
    """Chunk splitting must not inflate the serving stats: one caller batch
    of R requests records exactly R requests and R latency samples no matter
    how many spans the workers scored, and ``ServeStats.merge`` folds the
    per-batch accumulator without double counting."""
    params = _params()
    rng = np.random.default_rng(23)
    sizes = (3, 9, 17, 5, 12, 2, 8, 1)
    for workers in (1, 4):
        eng = _engine(params, quantized=True, fused=True, parallel=workers)
        reqs = [_req(rng, n) for n in sizes]
        eng.score_batch(reqs)
        assert eng.stats.requests == len(sizes)
        assert len(eng.stats._latencies_s) == len(sizes)
        assert eng.stats.candidates == sum(sizes)
        eng.close()
    a, b = ServeStats(), ServeStats()
    a.record(0.1, 10, requests=2)
    a.rows_scored = 7
    b.record(0.2, 5)
    b.rows_scored = 3
    a.merge(b)
    assert (a.requests, a.candidates, a.rows_scored) == (3, 15, 10)
    assert a.seconds == pytest.approx(0.3)
    assert list(a._latencies_s) == [0.1, 0.1, 0.2]


def test_parallel_span_planning_and_pool_mechanics():
    """The deterministic plumbing under the pipeline: near-equal contiguous
    spans (single span when parallelism can't help), fixed dispatch order
    from ``ScoringPool.run``, and gather-buffer recycling keyed by shape."""
    eng = _engine(_params(), quantized=True, fused=True, parallel=4)
    assert eng._plan_spans(1) == [(0, 1)]
    assert eng._plan_spans(8) == [(0, 2), (2, 4), (4, 6), (6, 8)]
    assert eng._plan_spans(5) == [(0, 2), (2, 3), (3, 4), (4, 5)]
    assert eng._plan_spans(3) == [(0, 1), (1, 2), (2, 3)]
    eng.close()
    single = _engine(_params(), quantized=True, fused=True, parallel=1)
    assert single._plan_spans(8) == [(0, 8)]
    single.close()

    pool = ScoringPool(2)
    buf = pool.acquire((4, 3), np.int8)
    assert buf.shape == (4, 3) and buf.dtype == np.int8
    pool.release(buf)
    assert pool.acquire((4, 3), np.int8) is buf  # recycled
    assert pool.acquire((4, 3), np.float32) is not buf  # keyed by dtype too
    order = []

    def prep(i):
        def go():
            time.sleep(0.002 * (5 - i))  # later preps finish *earlier*
            order.append(("p", i))
            return i
        return go

    def dispatch(i):
        order.append(("d", i))
        return i * 10

    assert pool.run([prep(i) for i in range(5)], dispatch) == [
        0, 10, 20, 30, 40]
    assert [i for k, i in order if k == "d"] == [0, 1, 2, 3, 4]
    pool.shutdown()
