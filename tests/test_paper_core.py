"""Paper-core behaviour: context cache, sparse updates, hogwild, DeepFFM."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.common.config import FFMConfig
from repro.common.metrics import roc_auc
from repro.core import deepffm, dcnv2, ffm, sparse_updates as SU
from repro.data.synthetic import CTRStream
from repro.serving.context_cache import CachedServer
from repro.train.hogwild import HogwildTrainer, make_local_sgd_round

CFG = FFMConfig(n_fields=12, context_fields=8, hash_space=2**14, k=4,
                mlp_hidden=(16, 8))


@pytest.mark.parametrize("model", ["deepffm", "ffm"])
def test_context_cache_equivalence(model):
    key = jax.random.PRNGKey(0)
    params = deepffm.init_params(CFG, key, model)
    params["lr"]["w"] = jax.random.normal(key, params["lr"]["w"].shape) * 0.1
    srv = CachedServer(CFG, params, model)
    stream = CTRStream(CFG, seed=3)
    for _ in range(3):
        ci, cv, ki, kv = stream.request(n_candidates=7)
        a = srv.serve(ci, cv, ki, kv)
        b = srv.serve_uncached(ci, cv, ki, kv)
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-5)


def test_context_cache_hit_path_reuses_entry():
    key = jax.random.PRNGKey(1)
    params = deepffm.init_params(CFG, key)
    srv = CachedServer(CFG, params, max_entries=2)
    stream = CTRStream(CFG, seed=4)
    ci, cv, ki, kv = stream.request(5)
    srv.serve(ci, cv, ki, kv)
    srv.serve(ci, cv, ki, kv)
    assert srv.hits == 1 and srv.misses == 1
    # LRU eviction
    for s in range(3):
        ci2, cv2, ki2, kv2 = stream.request(5)
        srv.serve(ci2, cv2, ki2, kv2)
    assert len(srv._cache) <= 2


def test_sparse_update_grads_equal_autodiff():
    key = jax.random.PRNGKey(2)
    ks = jax.random.split(key, 4)
    B, D, H = 32, 16, 24
    p = {"w0": jax.random.normal(ks[0], (D, H)) * 0.5, "b0": jnp.zeros(H),
         "w1": jax.random.normal(ks[1], (H, H)) * 0.5, "b1": jnp.zeros(H),
         "w2": jax.random.normal(ks[2], (H, 1)) * 0.5, "b2": jnp.zeros(1)}
    x = jax.random.normal(ks[3], (B, D))

    def dense(p):
        h = jnp.maximum(x @ p["w0"] + p["b0"], 0)
        h = jnp.maximum(h @ p["w1"] + p["b1"], 0)
        return jnp.sum((h @ p["w2"] + p["b2"]) ** 2)

    def sparse(p):
        return jnp.sum(SU.sparse_mlp_apply(p, x, 2) ** 2)

    gd, gs = jax.grad(dense)(p), jax.grad(sparse)(p)
    for k in p:
        np.testing.assert_allclose(np.asarray(gd[k]), np.asarray(gs[k]),
                                   rtol=1e-5, atol=1e-5)


def test_sparse_update_kernel_path_matches():
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (64, 32))
    w = jax.random.normal(jax.random.PRNGKey(4), (32, 48)) * 0.5
    b = jnp.zeros(48)

    def f(use_kernel):
        return jax.grad(
            lambda w_: jnp.sum(SU.relu_linear(x, w_, b, use_kernel) ** 2)
        )(w)

    np.testing.assert_allclose(np.asarray(f(False)), np.asarray(f(True)),
                               rtol=1e-4, atol=1e-4)


def test_skip_stats_speedup_grows_with_sparsity():
    masks_lo = [jnp.asarray(np.random.default_rng(0).random((64, 256)) < 0.9)]
    masks_hi = [jnp.asarray(np.random.default_rng(0).random((64, 256)) < 0.01)]
    lo = SU.skip_stats(masks_lo)
    hi = SU.skip_stats(masks_hi)
    assert hi["modeled_update_speedup"] > lo["modeled_update_speedup"]


def test_deepffm_beats_linear_on_interaction_data():
    """Paper Table 1's qualitative claim on our synthetic interaction stream."""
    cfg = CFG
    stream = CTRStream(cfg, seed=7)
    train = [stream.sample(512) for _ in range(150)]
    test = stream.sample(4096)

    def fit(model, lr=0.1):
        params = deepffm.init_params(cfg, jax.random.PRNGKey(0), model)
        vg = jax.jit(jax.value_and_grad(
            lambda p, b: deepffm.loss_fn(cfg, p, b, model)))
        acc = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape), params)
        for b in train:
            _, g = vg(params, b)
            acc = jax.tree_util.tree_map(lambda a, gg: a + gg * gg, acc, g)
            params = jax.tree_util.tree_map(
                lambda p, gg, a: p - lr * gg / jnp.sqrt(a + 1e-10), params, g, acc)
        probs = np.asarray(deepffm.predict_proba(
            cfg, params, test["idx"], test["val"], model))
        return roc_auc(test["label"], probs)

    auc_lin = fit("linear")
    auc_dffm = fit("deepffm")
    assert auc_dffm > auc_lin + 0.01, (auc_lin, auc_dffm)


def test_dcnv2_trains():
    cfg = CFG
    stream = CTRStream(cfg, seed=8)
    params = dcnv2.init_params(cfg, jax.random.PRNGKey(0))
    vg = jax.jit(jax.value_and_grad(lambda p, b: dcnv2.loss_fn(cfg, p, b)))
    losses = []
    for b in stream.batches(512, 30):
        l, g = vg(params, b)
        params = jax.tree_util.tree_map(lambda p, gg: p - 0.05 * gg, params, g)
        losses.append(float(l))
    assert np.mean(losses[-5:]) < np.mean(losses[:5])


def test_hogwild_converges_and_matches_control_quality():
    cfg = CFG
    stream = CTRStream(cfg, seed=9)
    test = stream.sample(4096)

    def auc(tr):
        probs = np.asarray(deepffm.predict_proba(
            cfg, tr.params(), jnp.asarray(test["idx"]), jnp.asarray(test["val"])))
        return roc_auc(test["label"], probs)

    tr1 = HogwildTrainer(cfg, lr=0.05, seed=0)
    tr1.train(stream.batches(256, 100), n_threads=1)
    a1 = auc(tr1)

    # The 4-thread run is racy by design: its quality depends on the thread
    # interleaving, which depends on machine load. One retry absorbs the
    # occasional unlucky schedule without weakening the qualitative claim.
    for attempt in range(2):
        tr4 = HogwildTrainer(cfg, lr=0.05, seed=0)
        tr4.train(CTRStream(cfg, seed=9).batches(256, 100), n_threads=4)
        a4 = auc(tr4)
        if a4 > 0.52 and a4 > a1 - 0.05:
            break
    # paper: "weight degradation due to Hogwild ... does not appear to cause
    # any noticeable drops"
    assert a4 > 0.52 and a4 > a1 - 0.05, (a1, a4)


def test_local_sgd_round_improves_loss():
    cfg = CFG
    stream = CTRStream(cfg, seed=10)
    params = deepffm.init_params(cfg, jax.random.PRNGKey(0))
    acc = jax.tree_util.tree_map(lambda p: jnp.zeros(p.shape), params)
    rnd = make_local_sgd_round(cfg, "deepffm", lr=0.05)
    W, K, B = 2, 4, 128
    losses = []
    for _ in range(6):
        bs = [[stream.sample(B) for _ in range(K)] for _ in range(W)]
        stacked = jax.tree_util.tree_map(
            lambda *x: jnp.stack(x),
            *[jax.tree_util.tree_map(lambda *x: jnp.stack(x), *wb) for wb in bs])
        params, acc, loss = rnd(params, acc, stacked)
        losses.append(float(loss))
    assert losses[-1] < losses[0]


def test_ffm_diagmask_pair_count():
    assert CFG.n_pairs == 12 * 11 // 2
    pi, pj = ffm.pair_indices(CFG.n_fields)
    assert (pi < pj).all()
