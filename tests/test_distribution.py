"""Distribution-layer tests: sharding rules (pure logic via AbstractMesh),
multi-device integration via subprocess (8 fake host devices), and the HLO
analyzer's accounting invariants."""
import jax
import jax.numpy as jnp
import pytest
from jax.sharding import PartitionSpec as P

from repro.launch import hlo_analysis, sharding
from repro.models.registry import get_config
from tests._subproc import run_with_devices


def _amesh():
    return sharding.abstract_mesh((16, 16), ("data", "model"))


def test_spec_for_divisibility_fallback():
    cfg = get_config("qwen2.5-3b")
    rules = sharding.logical_rules(cfg, _amesh())
    # kv_heads=2 not divisible by 16 -> replicated
    spec = sharding.spec_for((2048, 2, 128), ("embed", "kv_heads", "head_dim"),
                             rules, _amesh())
    assert spec == P(None, None, None)
    # heads=16 divisible -> sharded on model
    spec = sharding.spec_for((2048, 16, 128), ("embed", "heads", "head_dim"),
                             rules, _amesh())
    assert spec == P(None, "model", None)


def test_spec_for_no_double_axis_use():
    cfg = get_config("phi3.5-moe-42b-a6.6b")  # fsdp=True -> embed over data
    rules = sharding.logical_rules(cfg, _amesh())
    spec = sharding.spec_for((8192, 22016), ("embed", "mlp"), rules, _amesh())
    assert spec == P(("data",), "model") or spec == P("data", "model")
    used = set()
    for part in spec:
        if part is None:
            continue
        parts = part if isinstance(part, tuple) else (part,)
        assert not (set(parts) & used)
        used.update(parts)


def test_vocab_padding_is_shardable():
    for arch in ("llama3.2-1b", "seamless-m4t-large-v2", "mamba2-130m"):
        cfg = get_config(arch)
        assert cfg.padded_vocab % 16 == 0


def test_hlo_analyzer_counts_scan_trips():
    def g(x, ws):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, ws)[0]

    c = jax.jit(g).lower(
        jax.ShapeDtypeStruct((64, 32), jnp.float32),
        jax.ShapeDtypeStruct((5, 32, 32), jnp.float32),
    ).compile()
    r = hlo_analysis.analyze(c.as_text())
    assert r["flops_per_device"] == pytest.approx(5 * 2 * 64 * 32 * 32, rel=0.01)


def test_hlo_analyzer_dus_inplace_bytes():
    def f(cache, upd):
        return jax.lax.dynamic_update_slice(cache, upd, (0, 5))

    c = jax.jit(f, donate_argnums=0).lower(
        jax.ShapeDtypeStruct((1024, 1024), jnp.float32),
        jax.ShapeDtypeStruct((1024, 1), jnp.float32),
    ).compile()
    r = hlo_analysis.analyze(c.as_text())
    # in-place: ~2x the update slice, NOT 2x the 4MB cache
    assert r["bytes_per_device"] < 1024 * 1024 * 4


@pytest.mark.slow
def test_moe_expert_parallel_matches_dense_subprocess():
    out = run_with_devices("""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh
from repro.common import pspec
from repro.common.runtime import Runtime
from repro.models import moe
from repro.models.registry import get_config

cfg = get_config("phi3.5-moe-42b-a6.6b", smoke=True).replace(
    capacity_factor=8.0)  # high capacity -> no drops -> exact equivalence
mesh = Mesh(np.asarray(jax.devices()[:8]).reshape(2, 4), ("data", "model"))
rt = Runtime(mesh=mesh, data_axes=("data",))
p = pspec.materialize(moe.moe_specs(cfg), jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model))
with mesh:
    y_ep, aux_ep = jax.jit(lambda p, x: moe.moe_expert_parallel(cfg, p, x, rt))(p, x)
y_d, aux_d = moe.moe_dense(cfg, p, x)
err = float(jnp.max(jnp.abs(y_ep - y_d)))
rel = err / float(jnp.max(jnp.abs(y_d)))
assert rel < 1e-3, rel
assert abs(float(aux_ep) - float(aux_d)) < 1e-3
print("EP-OK", rel)
""", n_devices=8)
    assert "EP-OK" in out


@pytest.mark.slow
def test_small_mesh_train_step_subprocess():
    """End-to-end sharded train step on a 2x2 CPU mesh."""
    out = run_with_devices("""
import jax, jax.numpy as jnp
from repro.launch import mesh as mesh_lib, sharding
from repro.models import registry
from repro.optim import make_optimizer
from repro.train.steps import make_train_step

cfg = registry.get_config("llama3.2-1b", smoke=True)
mesh = mesh_lib.make_smoke_mesh(2, 2)
rt = mesh_lib.make_runtime(mesh)
params = registry.init_params(cfg, jax.random.PRNGKey(0))
p_axes = registry.param_axes(cfg)
p_abs = jax.tree_util.tree_map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), params)
p_sh = sharding.param_shardings(cfg, p_axes, p_abs, mesh)
params = jax.device_put(params, p_sh)
opt = make_optimizer("adam", lr=1e-3)
ostate = opt.init(params)
fn = jax.jit(make_train_step(cfg, opt, rt))
batch = {"tokens": jnp.zeros((4, 16), jnp.int32), "labels": jnp.zeros((4, 16), jnp.int32)}
step = jnp.zeros((), jnp.int32)
with mesh:
    for _ in range(2):
        params, ostate, step, m = fn(params, ostate, step, batch)
assert not jnp.isnan(m["loss"]), m
print("MESH-TRAIN-OK", float(m["loss"]))
""", n_devices=4)
    assert "MESH-TRAIN-OK" in out


@pytest.mark.slow
def test_dryrun_entrypoint_smoke_subprocess():
    """The real dryrun entrypoint on the production mesh (smallest arch)."""
    import subprocess, sys, os

    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "llama3.2-1b",
         "--shape", "long_500k", "--out", "/tmp/dryrun_test"],
        env=env, capture_output=True, text=True, timeout=580)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "long_500k" in proc.stdout


def test_hlo_analyzer_gather_row_bytes():
    """Embedding gathers cost ~selected rows, not the whole table."""
    def f(table, idx):
        return jnp.take(table, idx, axis=0)

    c = jax.jit(f).lower(
        jax.ShapeDtypeStruct((100_000, 64), jnp.float32),
        jax.ShapeDtypeStruct((32,), jnp.int32),
    ).compile()
    r = hlo_analysis.analyze(c.as_text())
    # 32 rows x 64 x 4B x small factor, NOT 25.6 MB
    assert r["bytes_per_device"] < 1_000_000
